//! The fleet runner: maintains a [`ServiceSpec`]'s tiers against the
//! world by driving the [`sim::Engine`](crate::sim::Engine) event loop
//! in a horizon-bounded steady-state loop — the first open-ended
//! workload in the crate (DESIGN.md §10).
//!
//! Model:
//!
//! * Ready replicas (fresh launches, revocation victims, re-pack
//!   migrants, burst scale-ups) are FFD-packed onto instances by the
//!   shared [`Packer`](crate::pack::Packer); each packed instance
//!   ("bin") gets its market from the policy — the bin is presented as
//!   one job whose length is the longest nominal replica session and
//!   whose footprint is the packed memory, so suitability/lifetime
//!   rules apply unchanged.  With k-way replication the k copies of a
//!   logical replica carry their replica id as a packing group, so the
//!   grouped packer never co-locates them (packed-bin replication).
//! * Open-ended tiers serve until the horizon: a replica session is a
//!   prologue (startup / recovery / re-pack transfer) followed by one
//!   serving span; "useful work" is uptime.  Batch tiers ride along
//!   with the DAG-style work/checkpoint timeline and finish early.
//! * A revocation kills every replica on the bin; each consults its FT
//!   mechanism.  What happens to the *survivors* is the
//!   [`RepackMode`]: `Incremental` (the default) leaves surviving bins
//!   untouched and instead lets the displaced copies warm-join their
//!   residual headroom (first-fit over ascending bin id, respecting
//!   capacity and replica anti-affinity) before the packer opens fresh
//!   bins; `Full` — the oracle the incremental path is tested against —
//!   drains every active bin, charges each in-flight copy a
//!   [`Category::Repack`] state-transfer prologue, and re-packs the
//!   whole fleet onto a fresh FFD packing; `Off` does neither.  Burst
//!   boundaries (autoscaling) consolidate only under `Full`.
//! * The deadline-slack SLO integral per tier (time under target) is
//!   assembled from per-copy uptime intervals (`service::fleet`) and
//!   lands in the tier ledger as the time-only [`Category::Slo`] row.
//!
//! Determinism: one `Rng` stream per seed, `BTreeMap` bin storage and
//! the engine's FIFO tie-break make runs a pure function of (world,
//! spec, policy, ft, rule, seed) — `tests/properties.rs` pins
//! worker-count independence for service sweeps on top of this.
//!
//! Equivalence anchor: the revocation-schedule rng uses stream
//! `0x51307F7` — exactly the stream `sim::run::execute` derives for a
//! job with id 0 — and session spans are replayed with the same
//! absolute-time arithmetic, so a single-tier, single-replica batch
//! service with re-packing disabled reproduces the corresponding
//! single-job `Scenario` run cost bit-for-bit
//! (`tests/service_equivalence.rs`).
//!
//! Hot path: session timelines live in a struct-of-arrays
//! [`SegArena`] (a bin stage holds a [`SegRange`], not an owning
//! vector), and every run borrows its working memory from a
//! caller-owned [`Scratch`] — see `sim::arena` and DESIGN.md §11.  The
//! arena replay primitives are bit-identical ports of the loops that
//! used to live here (pinned by `tests/engine_equivalence.rs`).

use std::collections::BTreeMap;

use super::fleet::{
    target_steps, union_intervals, violation_time, ServiceAggregate, ServiceResult, TierResult,
};
use super::spec::{RepackMode, ServiceSpec};
use crate::coordinator::Pool;
use crate::ft::{FtMechanism, Recovery};
use crate::job::{ContainerModel, Job, JobProgress};
use crate::market::session_cost;
use crate::obs::TraceEvent;
use crate::pack::Packer;
use crate::policy::{Ctx, Policy};
use crate::scenario::{FtKind, Scenario};
use crate::sim::accounting::{Category, Ledger};
use crate::sim::arena::{replay_spans, useful_done_abs, Scratch, SegArena, SegRange};
use crate::sim::engine::{Engine, Event};
use crate::sim::{RevocationRule, RunConfig, World};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------
// scenario bridge

/// A [`Scenario`] with a service fleet attached: the builder's policy /
/// FT / rule / start / seed settings drive [`FleetRunner`] over the
/// spec.
#[derive(Clone, Debug)]
pub struct ServiceScenario<'w> {
    scen: Scenario<'w>,
    spec: ServiceSpec,
}

impl<'w> ServiceScenario<'w> {
    /// Build from an already-configured scenario.  Panics on an invalid
    /// spec (load TOML specs through [`ServiceSpec::load`] to get a
    /// `Result` instead).
    pub fn from_scenario(scen: Scenario<'w>, spec: ServiceSpec) -> ServiceScenario<'w> {
        if let Err(e) = spec.validate() {
            panic!("invalid service spec: {e}");
        }
        ServiceScenario { scen, spec }
    }

    /// The validated service spec this scenario runs.
    pub fn spec(&self) -> &ServiceSpec {
        &self.spec
    }

    /// Run once with the scenario's configured seed.
    pub fn run(&self) -> ServiceResult {
        self.run_seeded(self.scen.seed_value())
    }

    /// Run once with an explicit seed.
    pub fn run_seeded(&self, seed: u64) -> ServiceResult {
        self.run_seeded_in(&mut Scratch::new(), seed)
    }

    /// [`ServiceScenario::run_seeded`] with caller-owned working memory
    /// (segment arena + sweep buffers); identical results for any
    /// scratch state.
    pub fn run_seeded_in(&self, scratch: &mut Scratch, seed: u64) -> ServiceResult {
        let policy = self.scen.build_policy();
        let mut runner = FleetRunner::with_policy(
            self.scen.world(),
            &self.spec,
            policy,
            self.scen.ft_kind(),
            self.scen.run_config(),
        );
        runner.run_in(scratch, seed)
    }

    /// `n_seeds` replicates (seeds `seed .. seed + n`), serially.
    pub fn replicate(&self, n_seeds: u64) -> ServiceAggregate {
        let base = self.scen.seed_value();
        let mut scratch = Scratch::new();
        let runs: Vec<ServiceResult> =
            (0..n_seeds).map(|i| self.run_seeded_in(&mut scratch, base + i)).collect();
        ServiceAggregate::from_runs(&runs)
    }

    /// Like [`ServiceScenario::replicate`] but fanned out over `pool`
    /// at per-seed steal granularity; identical for any worker count.
    pub fn replicate_on(&self, pool: &Pool, n_seeds: u64) -> ServiceAggregate {
        let base = self.scen.seed_value();
        let runs: Vec<ServiceResult> = pool.map_with(
            (0..n_seeds).collect(),
            1,
            Scratch::new,
            |scratch, _, i| self.run_seeded_in(scratch, base + i),
        );
        ServiceAggregate::from_runs(&runs)
    }
}

// ---------------------------------------------------------------------
// runner

/// Drives one service fleet execution.  Prefer the
/// [`Scenario::service`] / [`Sweep`](crate::scenario::Sweep) entry
/// points; this type is the engine room they share.
pub struct FleetRunner<'a> {
    world: &'a World,
    spec: &'a ServiceSpec,
    policy: Box<dyn Policy>,
    ft: FtKind,
    cfg: RunConfig,
}

impl<'a> FleetRunner<'a> {
    /// Build a runner with an explicit policy instance (the generic entry; [`FleetRunner::new`] wraps the standard kinds).
    pub fn with_policy(
        world: &'a World,
        spec: &'a ServiceSpec,
        policy: Box<dyn Policy>,
        ft: FtKind,
        cfg: RunConfig,
    ) -> FleetRunner<'a> {
        FleetRunner { world, spec, policy, ft, cfg }
    }

    /// Execute the fleet once; a pure function of the constructor
    /// inputs plus `seed`.
    pub fn run(&mut self, seed: u64) -> ServiceResult {
        self.run_in(&mut Scratch::new(), seed)
    }

    /// [`FleetRunner::run`] with caller-owned working memory: the
    /// segment arena, Count-threshold buffer, and frontier-sweep
    /// buffers are borrowed from `scratch` (cleared on entry, capacity
    /// kept for the next run).  Identical results for any scratch
    /// state.
    pub fn run_in(&mut self, scratch: &mut Scratch, seed: u64) -> ServiceResult {
        self.spec.validate().unwrap_or_else(|e| panic!("invalid service spec: {e}"));
        scratch.arena.clear();
        let capacity = self
            .spec
            .effective_capacity(&self.world.catalog)
            .unwrap_or_else(|e| panic!("{e}"));
        let t0 = self.cfg.start_t;
        let horizon_end = t0 + self.spec.horizon_h;

        // replication degree (packed-bin mode): k copies per logical
        // replica, spread across bins by the grouped packer
        let probe = Job::new(0, 1.0, 1.0);
        let degree = self.ft.build(&probe).degree().max(1);

        // logical replicas for the base targets, in tier order
        let mut replicas: Vec<Replica> = Vec::new();
        for (ti, tier) in self.spec.tiers.iter().enumerate() {
            for ri in 0..tier.replicas {
                replicas.push(Replica::new(self.spec, ti, ri, replicas.len() as u64, &self.ft));
            }
        }
        let mut copies: Vec<ReplicaCopy> = Vec::new();
        for (li, r) in replicas.iter().enumerate() {
            for ci in 0..degree {
                copies.push(ReplicaCopy::new(li, ci, r.tier));
            }
        }

        // The schedule rng uses the same stream `sim::run::execute`
        // derives for job id 0, so the degenerate single-replica fleet
        // consumes revocation draws in lockstep with the single-job
        // engine (the bit-for-bit equivalence anchor).
        let mut rng = Rng::with_stream(seed, 0x51307F7);
        let schedule = match self.cfg.rule {
            RevocationRule::Trace => FleetSchedule::Trace,
            RevocationRule::ForcedRate { per_day } => {
                let per_h = (per_day / 24.0).max(1e-9);
                FleetSchedule::Rate { per_h, next_abs: t0 + rng.exp(per_h) }
            }
            RevocationRule::ForcedCount { total } => {
                // sorted-uniform fractions of the fleet's expected work,
                // capped below 0.98 (the single-job rule, fleet-wide;
                // built into the scratch buffer — same draws, same sort,
                // same values, the scratch only donates capacity)
                let mut fr = std::mem::take(&mut scratch.thresholds);
                fr.clear();
                fr.extend((0..total).map(|_| rng.f64() * 0.98));
                fr.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let total_work = self.spec.total_work_h();
                for f in fr.iter_mut() {
                    *f *= total_work;
                }
                FleetSchedule::Count { thresholds: fr, idx: 0 }
            }
        };

        self.policy.reset();
        let policy_name = self.policy.name().to_string();
        if scratch.trace.is_on() {
            scratch.trace.emit(
                t0,
                TraceEvent::RunStart {
                    policy: policy_name.clone(),
                    ft: self.ft.label(),
                    rule: self.cfg.rule.label(),
                },
            );
        }
        let mut sim = Sim {
            world: self.world,
            spec: self.spec,
            policy: self.policy.as_mut(),
            cfg: &self.cfg,
            scratch: &mut *scratch,
            packer: Packer::new(capacity),
            rng,
            schedule,
            ft_kind: self.ft,
            degree,
            t_start: t0,
            horizon_end,
            replicas,
            copies,
            active: BTreeMap::new(),
            next_bin: 0,
            bins_launched: 0,
            bin_revocations: 0,
            fleet_repacks: 0,
            aborted: false,
            ended: false,
            revoked_markets: Vec::new(),
            w_closed: 0.0,
            count_gen: 0,
            rate_armed: false,
            rate_gen: 0,
            burst_events: Vec::new(),
            peak_bin_used_gb: 0.0,
            copack_conflicts: 0,
        };

        let mut engine = Engine::new();
        // horizon close for the steady-state loop (batch-only fleets
        // may drain the queue earlier; the handler then no-ops)
        engine.schedule_at(horizon_end, Event::Timer { tag: tag(K_HORIZON, 0, 0) });
        // burst boundaries, precomputed from the periodic windows
        for (ti, tier) in self.spec.tiers.iter().enumerate() {
            if tier.burst.is_none() {
                continue;
            }
            for &(bt, target) in target_steps(tier, t0, horizon_end).iter().skip(1) {
                let id = sim.burst_events.len() as u64;
                sim.burst_events.push((bt, ti, target));
                engine.schedule_at(bt, Event::Timer { tag: tag(K_BURST, 0, id) });
            }
        }
        sim.launch_ready(&mut engine, t0);
        sim.arm_rate(&mut engine);
        sim.resched_count(&mut engine, t0);

        while let Some((t, ev)) = engine.next() {
            if let Event::Timer { tag } = ev {
                let (kind, gen, id) = untag(tag);
                match kind {
                    K_COPY_DONE => sim.on_copy_done(&mut engine, t, gen, id as usize),
                    K_BIN_REVOKE => sim.on_trace_revoke(&mut engine, t, id),
                    K_RATE => sim.on_rate(&mut engine, t, gen),
                    K_COUNT => sim.on_count(&mut engine, t, gen),
                    K_HORIZON => sim.on_horizon(&mut engine, t),
                    K_BURST => sim.on_burst(&mut engine, t, id as usize),
                    _ => {}
                }
            }
        }

        let result = sim.finish(policy_name, self.ft.label(), capacity);
        // hand the Count-threshold buffer back to the scratch for the
        // next run (destructure first: `sim` holds the scratch borrow)
        let Sim { schedule, .. } = sim;
        if let FleetSchedule::Count { thresholds, .. } = schedule {
            scratch.thresholds = thresholds;
        }
        let t_end = engine.now().max(t0);
        scratch.trace.emit(t_end, TraceEvent::EngineDrained { events: engine.processed() });
        scratch
            .trace
            .emit(t_end, TraceEvent::RunEnd { completed: result.completed, cost: result.cost_usd() });
        result
    }
}

// ---------------------------------------------------------------------
// internal machinery

/// Engine timer-tag layout: `kind << 56 | (gen & 0xFF_FFFF) << 32 | id`
/// (the DAG runner's scheme).  Generations invalidate events that
/// outlive the session (or arming) that created them.
const K_COPY_DONE: u64 = 1;
const K_BIN_REVOKE: u64 = 2;
const K_RATE: u64 = 3;
const K_COUNT: u64 = 4;
const K_HORIZON: u64 = 5;
const K_BURST: u64 = 6;

#[inline]
fn tag(kind: u64, gen: u64, id: u64) -> u64 {
    (kind << 56) | ((gen & 0xFF_FFFF) << 32) | (id & 0xFFFF_FFFF)
}

#[inline]
fn untag(t: u64) -> (u64, u64, u64) {
    (t >> 56, (t >> 32) & 0xFF_FFFF, t & 0xFFFF_FFFF)
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum CState {
    Ready,
    Running,
    Done,
    Retired,
}

/// State carried into a copy's next session.
#[derive(Clone, Copy, Debug)]
enum Carry {
    Fresh,
    /// restart: boot + restore `recovery_h` of durable state
    Recover(f64),
    /// live migration within the notice: transfer instead of boot
    Migrate(f64),
    /// planned fleet re-pack: state transfer, progress preserved
    Repack(f64),
}

/// A batch replica's planned timeline within one session — prologue,
/// then work chunks interleaved with checkpoints, mirroring
/// `sim::run`'s inner loop arithmetic exactly.  Segments land in the
/// run's [`SegArena`]; the returned [`SegRange`] is the copy's handle
/// for replay via [`replay_spans`] / [`useful_done_abs`].
fn build_batch_segments(
    arena: &mut SegArena,
    job: &Job,
    ft: &dyn FtMechanism,
    container: &ContainerModel,
    p0: f64,
    frontier: f64,
    carry: Carry,
) -> SegRange {
    let lo = arena.start();
    push_prologue(arena, container, carry);
    let interval = ft.checkpoint_interval(job);
    let ckpt_dur = ft.checkpoint_time(job, container);
    let len = job.exec_len_h;
    let mut pos = p0;
    let mut since_ckpt = 0.0f64;
    while pos < len - 1e-9 {
        let until_ckpt = interval.map(|i| (i - since_ckpt).max(1e-6)).unwrap_or(f64::INFINITY);
        let chunk = (len - pos).min(until_ckpt);
        let reexec = (frontier - pos).clamp(0.0, chunk);
        if reexec > 0.0 {
            arena.push(Category::Reexec, reexec, false, false);
        }
        let useful = chunk - reexec;
        if useful > 0.0 {
            arena.push(Category::Useful, useful, true, false);
        }
        pos += chunk;
        since_ckpt += chunk;
        if let Some(i) = interval {
            if since_ckpt >= i - 1e-9 && pos < len - 1e-9 {
                arena.push(Category::Checkpoint, ckpt_dur, false, true);
                since_ckpt = 0.0;
            }
        }
    }
    arena.finish(lo)
}

/// An open-ended replica's session: prologue, then one serving span to
/// the horizon.  Uptime has no work target to protect, so no
/// checkpoint spans — an FT mechanism shows up as the recovery
/// prologue it charges after a revocation.
fn build_open_segments(
    arena: &mut SegArena,
    container: &ContainerModel,
    carry: Carry,
    t0: f64,
    horizon_end: f64,
) -> SegRange {
    let lo = arena.start();
    push_prologue(arena, container, carry);
    // absolute accumulation, matching the span replay
    let tt = t0 + arena.total_dur(arena.finish(lo));
    let serve = horizon_end - tt;
    if serve > 0.0 {
        arena.push(Category::Useful, serve, true, false);
    }
    arena.finish(lo)
}

fn push_prologue(arena: &mut SegArena, container: &ContainerModel, carry: Carry) {
    match carry {
        Carry::Migrate(m) => arena.push(Category::Migration, m, false, false),
        Carry::Repack(r) => arena.push(Category::Repack, r, false, false),
        Carry::Fresh => arena.push(Category::Startup, container.startup_time(), false, false),
        Carry::Recover(r) => {
            arena.push(Category::Startup, container.startup_time(), false, false);
            if r > 0.0 {
                arena.push(Category::Recovery, r, false, false);
            }
        }
    }
}

#[derive(Debug)]
enum FleetSchedule {
    Trace,
    Rate { per_h: f64, next_abs: f64 },
    Count { thresholds: Vec<f64>, idx: usize },
}

/// One logical replica of a tier.
struct Replica {
    tier: usize,
    job: Job,
    ft: Box<dyn FtMechanism>,
    batch: bool,
    progress: JobProgress,
    frontier: f64,
    ledger: Ledger,
    /// per-copy uptime intervals (unioned for the SLO integral)
    ups: Vec<Vec<(f64, f64)>>,
    done: bool,
    retired: bool,
    /// allocated by a burst scale-up (retired first at scale-down)
    burst_extra: bool,
    repacks: u32,
    completed_at: f64,
}

impl Replica {
    fn new(spec: &ServiceSpec, ti: usize, ri: u32, id: u64, ft: &FtKind) -> Replica {
        let tier = &spec.tiers[ti];
        let len = tier.run_h.unwrap_or(spec.horizon_h);
        let job = Job::new(id, len, tier.mem_gb).named(format!("{}-{ri}", tier.name));
        let mech = ft.build(&job);
        Replica {
            tier: ti,
            job,
            ft: mech,
            batch: tier.is_batch(),
            progress: JobProgress::new(),
            frontier: 0.0,
            ledger: Ledger::new(),
            ups: Vec::new(),
            done: false,
            retired: false,
            burst_extra: false,
            repacks: 0,
            completed_at: -1.0,
        }
    }
}

/// One physical placement slot: copy `copy_idx` of a logical replica
/// (`copy_idx == 0` is the lead; standbys exist under replication).
struct ReplicaCopy {
    replica: usize,
    copy_idx: u32,
    tier: usize,
    state: CState,
    carry: Carry,
    gen: u64,
    bin: u64,
    sessions: u32,
}

impl ReplicaCopy {
    fn new(replica: usize, copy_idx: u32, tier: usize) -> ReplicaCopy {
        ReplicaCopy {
            replica,
            copy_idx,
            tier,
            state: CState::Ready,
            carry: Carry::Fresh,
            gen: 0,
            bin: 0,
            sessions: 0,
        }
    }
}

struct BinStage {
    cid: usize,
    /// memory share of the instance price this copy pays
    share: f64,
    standby: bool,
    /// this session's timeline, as a range into the run's [`SegArena`]
    segments: SegRange,
    /// natural session end (absolute hours, accumulated like the
    /// single-job engine's clock)
    end_abs: f64,
    /// absolute time the copy comes up (prologue end); serving/work
    /// time and the SLO integral start here
    up_from_abs: f64,
    done: bool,
    /// when `done`: the absolute time the copy stopped (its natural
    /// end, an early stop, or a retirement) — idle share accrues from
    /// here to the bin close
    closed_abs: f64,
}

struct ActiveBin {
    t0: f64,
    end_t: f64,
    market: usize,
    is_spot: bool,
    /// instance $/h, fixed at session start (as in `sim::run`)
    price: f64,
    /// memory claimed by the packed copies (grows when an incremental
    /// re-pack warm-joins a displaced copy)
    used_gb: f64,
    stages: Vec<BinStage>,
    live: usize,
}

struct Sim<'a> {
    world: &'a World,
    spec: &'a ServiceSpec,
    policy: &'a mut dyn Policy,
    cfg: &'a RunConfig,
    /// caller-owned working memory: the segment arena plus the
    /// frontier-sweep buffers reused by [`Sim::resched_count`]
    scratch: &'a mut Scratch,
    packer: Packer,
    rng: Rng,
    schedule: FleetSchedule,
    ft_kind: FtKind,
    degree: u32,
    t_start: f64,
    horizon_end: f64,
    replicas: Vec<Replica>,
    copies: Vec<ReplicaCopy>,
    active: BTreeMap<u64, ActiveBin>,
    next_bin: u64,
    bins_launched: u32,
    bin_revocations: u32,
    fleet_repacks: u32,
    aborted: bool,
    ended: bool,
    /// markets whose revocations the policy is re-taught at every bin
    /// launch (per-bin policies are reset because each bin is a
    /// different "job"; the replay keeps the shrinking candidate set
    /// across the whole fleet, as in the DAG runner)
    revoked_markets: Vec<usize>,
    /// frontier work banked by finalized / killed sessions (Count rule)
    w_closed: f64,
    count_gen: u64,
    rate_armed: bool,
    rate_gen: u64,
    burst_events: Vec<(f64, usize, u32)>,
    peak_bin_used_gb: f64,
    copack_conflicts: u32,
}

impl Sim<'_> {
    fn all_batch_done(&self) -> bool {
        self.replicas.iter().all(|r| !r.batch || r.done || r.retired)
    }

    fn fleet_finished(&self) -> bool {
        self.ended || (self.spec.is_batch_only() && self.all_batch_done())
    }

    /// Pack every ready copy into bins and launch them at `t`.  Under
    /// [`RepackMode::Incremental`] displaced copies first warm-join the
    /// residual headroom of surviving bins (see [`Sim::join_bin`]);
    /// only the overflow reaches the packer.
    fn launch_ready(&mut self, eng: &mut Engine, t: f64) {
        if self.ended || self.aborted || t >= self.horizon_end {
            return;
        }
        let grouped = self.degree > 1;
        let mut ready: Vec<(usize, f64, u64)> = (0..self.copies.len())
            .filter(|&c| {
                let cp = &self.copies[c];
                let r = &self.replicas[cp.replica];
                cp.state == CState::Ready && !r.done && !r.retired
            })
            .map(|c| {
                let cp = &self.copies[c];
                let group =
                    if grouped { cp.replica as u64 } else { u64::MAX - 1 - c as u64 };
                (c, self.replicas[cp.replica].job.mem_gb, group)
            })
            .collect();
        if self.spec.repack == RepackMode::Incremental && !self.active.is_empty() {
            // incremental re-pack: first-fit over ascending bin id,
            // respecting capacity, remaining bin life, and replica
            // anti-affinity; overflow falls through to the packer
            let cap = self.packer.capacity_gb();
            let mut overflow = Vec::with_capacity(ready.len());
            for (c, mem, group) in ready {
                let li = self.copies[c].replica;
                let target = self
                    .active
                    .iter()
                    .find(|(_, b)| {
                        b.used_gb + mem <= cap + 1e-9
                            && t < b.end_t
                            && !b.stages.iter().any(|o| self.copies[o.cid].replica == li)
                    })
                    .map(|(&id, _)| id);
                match target {
                    Some(id) => self.join_bin(eng, t, c, id),
                    None => overflow.push((c, mem, group)),
                }
            }
            ready = overflow;
        }
        if ready.is_empty() {
            return;
        }
        let container = &self.world.container;
        for bin in self.packer.pack_grouped(&ready) {
            if self.bins_launched >= self.cfg.max_sessions {
                // safety valve: copies stay Ready, run reports !completed
                self.aborted = true;
                return;
            }
            self.bins_launched += 1;
            self.peak_bin_used_gb = self.peak_bin_used_gb.max(bin.used_gb);
            // belt-and-braces: the grouped packer must never co-pack
            // two copies of one logical replica
            if grouped {
                for (i, &a) in bin.stages.iter().enumerate() {
                    for &b in &bin.stages[i + 1..] {
                        if self.copies[a].replica == self.copies[b].replica {
                            self.copack_conflicts += 1;
                        }
                    }
                }
            }
            let bin_id = self.next_bin;
            self.next_bin += 1;
            // nominal length: the longest full replica session packed
            // (batch budget, or horizon remainder for open tiers), so
            // the policy's suitability/lifetime rules see the job the
            // fleet actually runs — and, for the degenerate case, the
            // same length the single-job engine passes
            let nominal = bin
                .stages
                .iter()
                .map(|&c| {
                    let r = &self.replicas[self.copies[c].replica];
                    if r.batch { r.job.exec_len_h } else { (self.horizon_end - t).max(1e-6) }
                })
                .fold(0.0f64, f64::max);
            let bin_job =
                Job::new(bin_id, nominal.max(1e-6), bin.used_gb).named(format!("svc-bin-{bin_id}"));
            let ctx = Ctx { world: self.world, now: t };
            self.policy.reset();
            for &m in &self.revoked_markets {
                self.policy.on_revocation(&bin_job, m, &ctx);
            }
            let decision = self.policy.select(&bin_job, &ctx);
            let market = decision.market();
            let is_spot = decision.is_spot();
            let price = if is_spot {
                self.world.market(market).price_at(t) as f64
            } else {
                self.world.od_price(market)
            };
            self.scratch.trace.emit(
                t,
                TraceEvent::PolicyDecision { job: bin_id, market: market as u64, spot: is_spot },
            );
            self.scratch.trace.emit(
                t,
                TraceEvent::BidPlaced { job: bin_id, market: market as u64, price, spot: is_spot },
            );
            let mut stages = Vec::with_capacity(bin.stages.len());
            let mut end_t = t;
            for &c in &bin.stages {
                let cp = &mut self.copies[c];
                let r = &self.replicas[cp.replica];
                let standby = cp.copy_idx != 0;
                let segments = if r.batch {
                    build_batch_segments(
                        &mut self.scratch.arena,
                        &r.job,
                        r.ft.as_ref(),
                        container,
                        r.progress.total_h(),
                        r.frontier,
                        cp.carry,
                    )
                } else {
                    build_open_segments(
                        &mut self.scratch.arena,
                        container,
                        cp.carry,
                        t,
                        self.horizon_end,
                    )
                };
                // the session clock accumulates absolutely, one span at
                // a time — the single-job engine's arithmetic
                let mut tt = t;
                let mut up_from = t;
                let mut in_prologue = true;
                for s in self.scratch.arena.iter(segments) {
                    if in_prologue
                        && !matches!(
                            s.cat,
                            Category::Startup
                                | Category::Recovery
                                | Category::Migration
                                | Category::Repack
                        )
                    {
                        up_from = tt;
                        in_prologue = false;
                    }
                    tt += s.dur;
                }
                if in_prologue {
                    up_from = tt; // prologue swallowed the session
                }
                let end_abs = if r.batch { tt } else { self.horizon_end };
                end_t = end_t.max(end_abs);
                cp.state = CState::Running;
                cp.gen += 1;
                cp.bin = bin_id;
                cp.sessions += 1;
                cp.carry = Carry::Fresh; // consumed by this session
                if r.batch {
                    eng.schedule_at(
                        end_abs,
                        Event::Timer { tag: tag(K_COPY_DONE, cp.gen, c as u64) },
                    );
                }
                stages.push(BinStage {
                    cid: c,
                    share: r.job.mem_gb / bin.used_gb,
                    standby,
                    segments,
                    end_abs,
                    up_from_abs: up_from,
                    done: false,
                    closed_abs: end_abs,
                });
            }
            if is_spot {
                if let FleetSchedule::Trace = self.schedule {
                    if let Some(rev) = self.world.market(market).next_revocation_after(t) {
                        if rev < end_t {
                            let revoke = Event::Timer { tag: tag(K_BIN_REVOKE, 0, bin_id) };
                            eng.schedule_at(rev, revoke);
                        }
                    }
                }
            }
            let live = stages.len();
            self.active.insert(
                bin_id,
                ActiveBin {
                    t0: t,
                    end_t,
                    market,
                    is_spot,
                    price,
                    used_gb: bin.used_gb,
                    stages,
                    live,
                },
            );
        }
    }

    /// Incremental re-pack: warm-join ready copy `c` onto surviving bin
    /// `bin_id` at `t`, consuming its residual headroom.  The joiner
    /// keeps its FT carry (no [`Category::Repack`] charge — survivors
    /// are never drained, so there is no planned state transfer to
    /// pay), pays its memory share of the instance price from `t`
    /// onward, and may extend the bin's natural end.  Survivor shares
    /// stay fixed at their launch packing.
    fn join_bin(&mut self, eng: &mut Engine, t: f64, c: usize, bin_id: u64) {
        let li = self.copies[c].replica;
        let standby = self.copies[c].copy_idx != 0;
        let carry = self.copies[c].carry;
        let batch = self.replicas[li].batch;
        let mem = self.replicas[li].job.mem_gb;
        let container = &self.world.container;
        let segments = if batch {
            let r = &self.replicas[li];
            build_batch_segments(
                &mut self.scratch.arena,
                &r.job,
                r.ft.as_ref(),
                container,
                r.progress.total_h(),
                r.frontier,
                carry,
            )
        } else {
            build_open_segments(&mut self.scratch.arena, container, carry, t, self.horizon_end)
        };
        // absolute session clock, as in the launch path
        let mut tt = t;
        let mut up_from = t;
        let mut in_prologue = true;
        for s in self.scratch.arena.iter(segments) {
            if in_prologue
                && !matches!(
                    s.cat,
                    Category::Startup
                        | Category::Recovery
                        | Category::Migration
                        | Category::Repack
                )
            {
                up_from = tt;
                in_prologue = false;
            }
            tt += s.dur;
        }
        if in_prologue {
            up_from = tt; // prologue swallowed the session
        }
        let end_abs = if batch { tt } else { self.horizon_end };

        let cp = &mut self.copies[c];
        cp.state = CState::Running;
        cp.gen += 1;
        cp.bin = bin_id;
        cp.sessions += 1;
        cp.carry = Carry::Fresh; // consumed by this session
        if batch {
            eng.schedule_at(end_abs, Event::Timer { tag: tag(K_COPY_DONE, cp.gen, c as u64) });
        }

        let bin = self.active.get_mut(&bin_id).expect("joining unknown bin");
        bin.used_gb += mem;
        bin.stages.push(BinStage {
            cid: c,
            // the joiner's share reflects the updated footprint; the
            // survivors' sessions were priced at launch
            share: mem / bin.used_gb,
            standby,
            segments,
            end_abs,
            up_from_abs: up_from,
            done: false,
            closed_abs: end_abs,
        });
        bin.live += 1;
        let old_end = bin.end_t;
        bin.end_t = bin.end_t.max(end_abs);
        self.peak_bin_used_gb = self.peak_bin_used_gb.max(bin.used_gb);
        // an extension can pull the bin's next trace revocation into
        // the (now longer) session window; at most one notice is ever
        // pending, because launch scheduled one only for rev < old_end
        if bin.is_spot && bin.end_t > old_end {
            if let FleetSchedule::Trace = self.schedule {
                if let Some(rev) = self.world.market(bin.market).next_revocation_after(bin.t0) {
                    if rev >= old_end && rev < bin.end_t {
                        eng.schedule_at(rev, Event::Timer { tag: tag(K_BIN_REVOKE, 0, bin_id) });
                    }
                }
            }
        }
    }

    /// Record a copy's up interval `[up_from, until)` if non-empty.
    fn record_up(&mut self, cid: usize, up_from: f64, until: f64) {
        let cp = &self.copies[cid];
        let r = &mut self.replicas[cp.replica];
        while r.ups.len() <= cp.copy_idx as usize {
            r.ups.push(Vec::new());
        }
        if until > up_from {
            r.ups[cp.copy_idx as usize].push((up_from, until));
        }
    }

    fn on_copy_done(&mut self, eng: &mut Engine, t: f64, gen: u64, cid: usize) {
        if self.ended || self.copies[cid].state != CState::Running {
            return;
        }
        if (self.copies[cid].gen & 0xFF_FFFF) != gen {
            return; // stale event from a killed session
        }
        let bin_id = self.copies[cid].bin;
        let li = self.copies[cid].replica;
        let (live_after, up_from) = {
            let bin = self.active.get_mut(&bin_id).expect("running copy without active bin");
            let pos = bin.stages.iter().position(|b| b.cid == cid).unwrap();
            let price = bin.price;
            let (t0, share, standby, up_from) = {
                let bs = &bin.stages[pos];
                (bin.t0, bs.share, bs.standby, bs.up_from_abs)
            };
            let r = &mut self.replicas[li];
            let useful = {
                let bs = &bin.stages[pos];
                replay_spans(
                    &mut r.ledger,
                    (!standby).then_some((&mut r.progress, &mut r.frontier)),
                    &self.scratch.arena,
                    bs.segments,
                    t0,
                    bs.end_abs,
                    price * share,
                    standby,
                )
            };
            self.w_closed += useful;
            if !standby {
                debug_assert!(r.progress.is_complete(&r.job));
                r.done = true;
                r.completed_at = t;
            }
            bin.stages[pos].done = true;
            bin.stages[pos].closed_abs = t;
            bin.live -= 1;
            (bin.live, up_from)
        };
        self.record_up(cid, up_from, t);
        self.copies[cid].state = CState::Done;
        if self.replicas[li].done {
            // the lead finished: stop the standbys still mirroring it
            self.stop_replica_copies(eng, t, li, CState::Done);
        }
        if live_after == 0 {
            self.close_bin(bin_id, t);
        }
        self.launch_ready(eng, t);
        self.arm_rate(eng);
        self.resched_count(eng, t);
    }

    /// Stop every still-running copy of logical replica `li` at `t`
    /// (lead completed, or the replica was retired): record spans and
    /// uptime up to `t`, convert the slot to an idle share, close bins
    /// that empty out.
    fn stop_replica_copies(&mut self, _eng: &mut Engine, t: f64, li: usize, to: CState) {
        let cids: Vec<usize> = (0..self.copies.len())
            .filter(|&c| self.copies[c].replica == li && self.copies[c].state == CState::Running)
            .collect();
        for cid in cids {
            let bin_id = self.copies[cid].bin;
            let (up_from, emptied) = {
                let bin = self.active.get_mut(&bin_id).expect("running copy without bin");
                let pos = bin.stages.iter().position(|b| b.cid == cid).unwrap();
                let price = bin.price;
                let (t0, share, standby, up_from) = {
                    let bs = &bin.stages[pos];
                    (bin.t0, bs.share, bs.standby, bs.up_from_abs)
                };
                let r = &mut self.replicas[li];
                let useful = {
                    let bs = &bin.stages[pos];
                    replay_spans(
                        &mut r.ledger,
                        (!standby).then_some((&mut r.progress, &mut r.frontier)),
                        &self.scratch.arena,
                        bs.segments,
                        t0,
                        t,
                        price * share,
                        standby,
                    )
                };
                self.w_closed += useful;
                bin.stages[pos].done = true;
                bin.stages[pos].closed_abs = t;
                bin.live -= 1;
                (up_from, bin.live == 0)
            };
            self.record_up(cid, up_from, t);
            self.copies[cid].state = to;
            self.copies[cid].gen += 1; // invalidate any pending K_COPY_DONE
            if emptied {
                self.close_bin(bin_id, t);
            }
        }
        // ready (unplaced) copies of the replica just change state
        for c in &mut self.copies {
            if c.replica == li && c.state == CState::Ready {
                c.state = to;
            }
        }
    }

    /// Natural close: bill the billing-cycle buffer and the idle-slot
    /// tails of copies that stopped before the bin did.
    fn close_bin(&mut self, bin_id: u64, end: f64) {
        let bin = self.active.remove(&bin_id).expect("closing unknown bin");
        let (_, buffer) = session_cost(end - bin.t0, bin.price);
        for bs in &bin.stages {
            let li = self.copies[bs.cid].replica;
            let ledger = &mut self.replicas[li].ledger;
            ledger.buffer_cost(buffer * bs.share);
            let idle = (end - bs.closed_abs).max(0.0);
            if idle > 0.0 {
                ledger.cost.add(Category::Idle, idle * bin.price * bs.share);
            }
        }
    }

    /// A revocation at `t_eff` kills every copy on the bin; each
    /// consults its FT mechanism (a running sibling copy absorbs the
    /// loss under replication).  What happens next is the
    /// [`RepackMode`]: `Full` drains and re-packs the whole surviving
    /// fleet, `Incremental` counts the consolidation and lets the
    /// victims warm-join survivors at the next launch, `Off` does
    /// neither.
    fn revoke_bin(&mut self, eng: &mut Engine, t_eff: f64, bin_id: u64) {
        let Some(bin) = self.active.remove(&bin_id) else {
            return; // closed at the same timestamp before the notice
        };
        self.bin_revocations += 1;
        self.scratch
            .trace
            .emit(t_eff, TraceEvent::Revocation { job: bin_id, market: bin.market as u64 });
        let (_, buffer) = session_cost(t_eff - bin.t0, bin.price);
        for bs in &bin.stages {
            let cid = bs.cid;
            let li = self.copies[cid].replica;
            self.replicas[li].ledger.buffer_cost(buffer * bs.share);
            if bs.done {
                // the copy had already stopped; it only idled from its
                // stop to the revocation
                let idle = (t_eff - bs.closed_abs).max(0.0);
                if idle > 0.0 {
                    self.replicas[li]
                        .ledger
                        .cost
                        .add(Category::Idle, idle * bin.price * bs.share);
                }
                continue;
            }
            let r = &mut self.replicas[li];
            let useful = replay_spans(
                &mut r.ledger,
                (!bs.standby).then_some((&mut r.progress, &mut r.frontier)),
                &self.scratch.arena,
                bs.segments,
                bin.t0,
                t_eff,
                bin.price * bs.share,
                bs.standby,
            );
            self.w_closed += useful;
            self.record_up(cid, bs.up_from_abs, t_eff.min(bs.end_abs).max(bs.up_from_abs));
            // a running sibling copy absorbs the loss (replication):
            // state lives in replica memory, the victim re-syncs on its
            // next boot
            let sibling_alive = self.copies.iter().enumerate().any(|(oc, c)| {
                oc != cid
                    && c.replica == li
                    && c.state == CState::Running
                    && !bin.stages.iter().any(|o| o.cid == oc)
            });
            let r = &mut self.replicas[li];
            if sibling_alive {
                r.progress.revocations += 1;
                self.copies[cid].carry = Carry::Fresh;
            } else {
                let rec = r.ft.on_revocation(
                    &r.job,
                    &self.world.container,
                    r.progress.durable_h > 0.0,
                );
                match rec {
                    Recovery::Restart { recovery_time_h } => {
                        r.progress.on_revocation();
                        self.copies[cid].carry = Carry::Recover(recovery_time_h);
                    }
                    Recovery::Migrate { migrate_time_h } => {
                        r.progress.revocations += 1;
                        self.copies[cid].carry = Carry::Migrate(migrate_time_h);
                    }
                }
            }
            self.copies[cid].state = CState::Ready;
            self.copies[cid].gen += 1; // invalidate the pending completion
        }
        self.revoked_markets.push(bin.market);
        match self.spec.repack {
            RepackMode::Full => self.fleet_repack(eng, t_eff.max(self.t_start)),
            RepackMode::Incremental => {
                // a consolidation event: the displaced copies warm-join
                // surviving bins at the next `launch_ready` instead of
                // draining the whole fleet (no survivor is touched, so
                // no `Category::Repack` transfer is charged)
                self.fleet_repacks += 1;
            }
            RepackMode::Off => {}
        }
    }

    /// Mid-session survivor re-packing — the [`RepackMode::Full`]
    /// oracle: drain every active bin at `t`, charge each in-flight
    /// copy a state-transfer prologue ([`Category::Repack`], progress
    /// preserved), and return the whole fleet to the packer for a
    /// fresh FFD consolidation.
    fn fleet_repack(&mut self, _eng: &mut Engine, t: f64) {
        // a consolidation event even when no surviving bin needs
        // draining (the fresh packing then starts from scratch)
        self.fleet_repacks += 1;
        let bins: Vec<u64> = self.active.keys().copied().collect();
        let n_bins = bins.len() as u64;
        let mut moved = 0u64;
        for bin_id in bins {
            let bin = self.active.remove(&bin_id).expect("repacking unknown bin");
            let (_, buffer) = session_cost(t - bin.t0, bin.price);
            for bs in &bin.stages {
                let cid = bs.cid;
                let li = self.copies[cid].replica;
                self.replicas[li].ledger.buffer_cost(buffer * bs.share);
                if bs.done {
                    let idle = (t - bs.closed_abs).max(0.0);
                    if idle > 0.0 {
                        self.replicas[li]
                            .ledger
                            .cost
                            .add(Category::Idle, idle * bin.price * bs.share);
                    }
                    continue;
                }
                let r = &mut self.replicas[li];
                let useful = replay_spans(
                    &mut r.ledger,
                    (!bs.standby).then_some((&mut r.progress, &mut r.frontier)),
                    &self.scratch.arena,
                    bs.segments,
                    bin.t0,
                    t,
                    bin.price * bs.share,
                    bs.standby,
                );
                self.w_closed += useful;
                self.record_up(cid, bs.up_from_abs, t.max(bs.up_from_abs));
                // planned move: progress survives, only the transfer is
                // paid on the next session's prologue
                let transfer = self.world.container.restore_time(r.job.mem_gb);
                r.repacks += 1;
                moved += 1;
                self.copies[cid].carry = Carry::Repack(transfer);
                self.copies[cid].state = CState::Ready;
                self.copies[cid].gen += 1;
            }
        }
        self.scratch.trace.emit(t, TraceEvent::Repack { bins: n_bins, moved });
    }

    fn on_trace_revoke(&mut self, eng: &mut Engine, t: f64, bin_id: u64) {
        if self.ended {
            return;
        }
        self.revoke_bin(eng, t, bin_id);
        self.launch_ready(eng, t);
        self.arm_rate(eng);
        self.resched_count(eng, t);
    }

    /// (Re)arm the ForcedRate chain: one pending timer at
    /// `max(now, next_abs)`, re-armed after every launch if it died out
    /// with no revocable bin.
    fn arm_rate(&mut self, eng: &mut Engine) {
        let next = match self.schedule {
            FleetSchedule::Rate { next_abs, .. } => next_abs,
            _ => return,
        };
        if self.rate_armed || self.ended || self.aborted || self.fleet_finished() {
            return;
        }
        self.rate_armed = true;
        self.rate_gen += 1;
        eng.schedule_at(next, Event::Timer { tag: tag(K_RATE, self.rate_gen, 0) });
    }

    /// ForcedRate arrival: revoke the lowest-id active spot bin still
    /// short of its natural end, then redraw the chain — the
    /// single-job engine's schedule, fleet-wide.  The *effective*
    /// revocation time is the drawn arrival (it can precede the bin
    /// launch after an on-demand stretch, exactly like the single-job
    /// engine's stale `next_abs`).
    fn on_rate(&mut self, eng: &mut Engine, _t: f64, gen: u64) {
        if (self.rate_gen & 0xFF_FFFF) != gen || self.ended {
            return;
        }
        self.rate_armed = false;
        let (per_h, t_eff) = match self.schedule {
            FleetSchedule::Rate { per_h, next_abs } => (per_h, next_abs),
            _ => return,
        };
        if self.fleet_finished() || self.aborted {
            return; // let the chain die out
        }
        let victim = self
            .active
            .iter()
            .find(|(_, b)| b.is_spot && t_eff < b.end_t)
            .map(|(&id, _)| id);
        let Some(id) = victim else {
            return; // nothing revocable; the next launch re-arms
        };
        self.revoke_bin(eng, t_eff, id);
        let redraw = t_eff + self.rng.exp(per_h);
        if let FleetSchedule::Rate { next_abs, .. } = &mut self.schedule {
            *next_abs = redraw;
        }
        let now = eng.now();
        self.launch_ready(eng, now.max(t_eff));
        self.arm_rate(eng);
        self.resched_count(eng, now);
    }

    /// (Re)schedule the next ForcedCount crossing: the wall time at
    /// which the fleet's global new-work frontier reaches the pending
    /// threshold, given the piecewise timelines of every active bin
    /// (the DAG runner's sweep, skipping standby mirrors).
    fn resched_count(&mut self, eng: &mut Engine, now: f64) {
        let thr = match &self.schedule {
            FleetSchedule::Count { thresholds, idx } => match thresholds.get(*idx) {
                Some(&thr) => thr,
                None => return,
            },
            _ => return,
        };
        if self.ended {
            return;
        }
        let Scratch { arena, spans, bounds, .. } = &mut *self.scratch;
        let mut w_now = self.w_closed;
        for b in self.active.values() {
            for bs in b.stages.iter().filter(|bs| !bs.done && !bs.standby) {
                w_now += useful_done_abs(arena, bs.segments, b.t0, now);
            }
        }
        let mut need = thr - w_now;
        let t_cross = if need <= 1e-12 {
            Some(now)
        } else {
            // the span and bound buffers live in the scratch: cleared
            // per call, capacity kept across calls and runs
            spans.clear();
            for b in self.active.values() {
                for bs in b.stages.iter().filter(|bs| !bs.done && !bs.standby) {
                    let mut off = b.t0;
                    for s in arena.iter(bs.segments) {
                        let (s0, s1) = (off, off + s.dur);
                        off = s1;
                        if s.advances && s1 > now + 1e-12 {
                            spans.push((s0.max(now), s1));
                        }
                    }
                }
            }
            bounds.clear();
            bounds.extend(spans.iter().flat_map(|&(a, b)| [a, b]));
            bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            bounds.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
            let mut found = None;
            for w in bounds.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                let rate =
                    spans.iter().filter(|&&(a, b)| a <= lo + 1e-12 && b >= hi - 1e-12).count();
                if rate == 0 {
                    continue;
                }
                let cap = rate as f64 * (hi - lo);
                if need <= cap + 1e-12 {
                    found = Some(lo + need / rate as f64);
                    break;
                }
                need -= cap;
            }
            found
        };
        self.count_gen += 1;
        if let Some(tc) = t_cross {
            eng.schedule_at(tc, Event::Timer { tag: tag(K_COUNT, self.count_gen, 0) });
        }
    }

    fn on_count(&mut self, eng: &mut Engine, t: f64, gen: u64) {
        if (self.count_gen & 0xFF_FFFF) != gen || self.ended {
            return; // superseded by a reschedule
        }
        // victim: prefer a spot bin actively advancing the frontier at
        // `t`; fall back to the lowest-id active spot bin
        let arena = &self.scratch.arena;
        let advancing = self
            .active
            .iter()
            .filter(|(_, b)| b.is_spot)
            .find(|(_, b)| {
                b.stages.iter().any(|bs| {
                    !bs.done && !bs.standby && {
                        let mut off = b.t0;
                        arena.iter(bs.segments).any(|s| {
                            let hit = s.advances && t >= off - 1e-9 && t <= off + s.dur + 1e-9;
                            off += s.dur;
                            hit
                        })
                    }
                })
            })
            .map(|(&id, _)| id);
        let victim =
            advancing.or_else(|| self.active.iter().find(|(_, b)| b.is_spot).map(|(&id, _)| id));
        let Some(id) = victim else {
            return; // nothing revocable right now; resched will retry
        };
        if let FleetSchedule::Count { idx, .. } = &mut self.schedule {
            *idx += 1;
        }
        self.revoke_bin(eng, t, id);
        self.launch_ready(eng, t);
        self.resched_count(eng, t);
    }

    /// Burst boundary: raise the tier's live replica set to the new
    /// target (allocating burst replicas) or retire the extras, then
    /// consolidate the fleet if re-packing is on.
    fn on_burst(&mut self, eng: &mut Engine, t: f64, ev: usize) {
        if self.ended || self.aborted {
            return;
        }
        let (_, ti, target) = self.burst_events[ev];
        let live: Vec<usize> = (0..self.replicas.len())
            .filter(|&li| {
                let r = &self.replicas[li];
                r.tier == ti && !r.retired && !r.done
            })
            .collect();
        let n = live.len() as u32;
        self.scratch
            .trace
            .emit(t, TraceEvent::Scale { tier: ti as u64, from: n as u64, to: target as u64 });
        match target.cmp(&n) {
            std::cmp::Ordering::Greater => {
                for _ in 0..(target - n) {
                    let id = self.replicas.len() as u64;
                    let mut r = Replica::new(self.spec, ti, id as u32, id, &self.ft_kind);
                    r.burst_extra = true;
                    let li = self.replicas.len();
                    self.replicas.push(r);
                    for ci in 0..self.degree {
                        self.copies.push(ReplicaCopy::new(li, ci, ti));
                    }
                }
            }
            std::cmp::Ordering::Less => {
                // retire burst extras first, newest first
                let mut excess = n - target;
                for &li in live.iter().rev() {
                    if excess == 0 {
                        break;
                    }
                    if self.replicas[li].burst_extra {
                        self.replicas[li].retired = true;
                        self.stop_replica_copies(eng, t, li, CState::Retired);
                        excess -= 1;
                    }
                }
            }
            std::cmp::Ordering::Equal => {}
        }
        // only the full oracle consolidates on autoscale boundaries;
        // incremental scale-ups warm-join through `launch_ready`
        if self.spec.repack == RepackMode::Full {
            self.fleet_repack(eng, t);
        }
        self.launch_ready(eng, t);
        self.arm_rate(eng);
        self.resched_count(eng, t);
    }

    /// Horizon close: drain every active bin at the window end; the
    /// steady-state loop is over.
    fn on_horizon(&mut self, _eng: &mut Engine, t: f64) {
        if self.ended {
            return;
        }
        self.ended = true;
        let bins: Vec<u64> = self.active.keys().copied().collect();
        for bin_id in bins {
            let bin = self.active.remove(&bin_id).expect("closing unknown bin");
            for bs in &bin.stages {
                if bs.done {
                    continue;
                }
                let cid = bs.cid;
                let li = self.copies[cid].replica;
                let r = &mut self.replicas[li];
                let useful = replay_spans(
                    &mut r.ledger,
                    (!bs.standby).then_some((&mut r.progress, &mut r.frontier)),
                    &self.scratch.arena,
                    bs.segments,
                    bin.t0,
                    t,
                    bin.price * bs.share,
                    bs.standby,
                );
                self.w_closed += useful;
                self.record_up(cid, bs.up_from_abs, t.max(bs.up_from_abs));
                self.copies[cid].state = CState::Done;
                self.copies[cid].gen += 1;
            }
            let (_, buffer) = session_cost(t - bin.t0, bin.price);
            for bs in &bin.stages {
                let li = self.copies[bs.cid].replica;
                self.replicas[li].ledger.buffer_cost(buffer * bs.share);
                if bs.done {
                    let idle = (t - bs.closed_abs).max(0.0);
                    if idle > 0.0 {
                        self.replicas[li]
                            .ledger
                            .cost
                            .add(Category::Idle, idle * bin.price * bs.share);
                    }
                }
            }
        }
    }

    /// Assemble the per-tier results: merged ledgers, the SLO integral
    /// (recorded as the time-only `slo` row), uptime, counters.
    fn finish(&mut self, policy: String, ft: String, capacity: f64) -> ServiceResult {
        let horizon_end = self.horizon_end;
        let t_start = self.t_start;
        let mut tiers = Vec::with_capacity(self.spec.tiers.len());
        for (ti, tier) in self.spec.tiers.iter().enumerate() {
            let mut ledger = Ledger::new();
            let mut revocations = 0u32;
            let mut sessions = 0u32;
            let mut repacks = 0u32;
            let mut completed = true;
            let mut up_h = 0.0f64;
            // first pass: the tier's observation window (batch tiers
            // are observed until their last replica completes)
            let mut window_end = if tier.is_batch() { t_start } else { horizon_end };
            for r in &self.replicas {
                if r.tier == ti && r.batch && !r.retired {
                    completed &= r.done;
                    window_end = window_end.max(if r.done { r.completed_at } else { horizon_end });
                }
            }
            let mut replica_ups: Vec<Vec<(f64, f64)>> = Vec::new();
            for r in &mut self.replicas {
                if r.tier != ti {
                    continue;
                }
                ledger.merge(&std::mem::take(&mut r.ledger));
                revocations += r.progress.revocations;
                repacks += r.repacks;
                let raw = union_intervals(r.ups.concat());
                up_h += raw.iter().map(|&(a, b)| b - a).sum::<f64>();
                let mut ups = raw;
                if r.batch && r.done && r.completed_at >= 0.0 {
                    // a completed batch replica has satisfied its
                    // demand: count it as up through the tier window so
                    // staggered completions never score as violations
                    ups.push((r.completed_at, window_end));
                }
                replica_ups.push(union_intervals(ups));
            }
            for cp in &self.copies {
                if cp.tier == ti {
                    sessions += cp.sessions;
                }
            }
            let steps = target_steps(tier, t_start, horizon_end);
            let viol = violation_time(&replica_ups, &steps, t_start, window_end);
            if viol > 0.0 {
                self.scratch
                    .trace
                    .emit(window_end, TraceEvent::SloViolation { tier: ti as u64, hours: viol });
            }
            let window_h = (window_end - t_start).max(0.0);
            ledger.time.add(Category::Slo, viol);
            let slo_frac = if window_h > 0.0 { viol / window_h } else { 0.0 };
            tiers.push(TierResult {
                name: tier.name.clone(),
                ledger,
                slo_violation_h: viol,
                slo_frac,
                slo_met: slo_frac <= tier.slack + 1e-12,
                target: tier.replicas,
                up_h,
                window_h,
                revocations,
                sessions,
                repacks,
                completed: completed && !self.aborted,
            });
        }
        let makespan_h = if self.spec.is_batch_only() && self.all_batch_done() {
            self.replicas
                .iter()
                .filter(|r| r.done)
                .map(|r| r.completed_at)
                .fold(t_start, f64::max)
                - t_start
        } else {
            self.spec.horizon_h
        };
        let completed = tiers.iter().all(|t| t.completed) && !self.aborted;
        ServiceResult {
            service: self.spec.name.clone(),
            policy,
            ft,
            tiers,
            makespan_h,
            horizon_h: self.spec.horizon_h,
            revocations: self.bin_revocations,
            bins: self.bins_launched,
            repacks: self.fleet_repacks,
            completed,
            capacity_gb: capacity,
            peak_bin_used_gb: self.peak_bin_used_gb,
            copack_conflicts: self.copack_conflicts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::PolicyKind;
    use crate::service::spec::TierSpec;

    fn world() -> (World, f64) {
        let mut w = World::generate(64, 1.0, 77);
        let start = w.split_train(0.6);
        (w, start)
    }

    fn web(horizon: f64) -> ServiceSpec {
        ServiceSpec::new("web")
            .horizon(horizon)
            .capacity(64.0)
            .tier(TierSpec::open("frontend", 3, 8.0).slack(0.2))
            .tier(TierSpec::open("api", 2, 16.0).slack(0.2))
    }

    #[test]
    fn steady_state_fleet_serves_to_horizon() {
        let (w, start) = world();
        let r = Scenario::on(&w)
            .policy(PolicyKind::OnDemand)
            .start_t(start)
            .seed(3)
            .service(web(24.0))
            .run();
        assert!(r.completed, "{r:?}");
        assert_eq!(r.revocations, 0, "on-demand bins are never revoked");
        assert_eq!(r.tiers.len(), 2);
        assert!((r.makespan_h - 24.0).abs() < 1e-9);
        for t in &r.tiers {
            // uptime ≈ replicas × (horizon − boot)
            assert!(t.up_h > 0.9 * t.target as f64 * 23.0, "{}: up {}", t.name, t.up_h);
            // only the boot is under target
            assert!(t.slo_violation_h < 0.5, "{}: slo {}", t.name, t.slo_violation_h);
            assert!(t.slo_met);
            assert!(t.ledger.time.get(Category::Useful) > 0.0);
        }
        assert!(r.cost_usd() > 0.0);
        assert!(r.peak_bin_used_gb <= r.capacity_gb + 1e-9);
    }

    #[test]
    fn batch_only_fleet_ends_early() {
        let (w, start) = world();
        let spec = ServiceSpec::new("batch")
            .horizon(100.0)
            .tier(TierSpec::batch("work", 2, 16.0, 4.0));
        let r = Scenario::on(&w)
            .policy(PolicyKind::OnDemand)
            .start_t(start)
            .seed(1)
            .service(spec)
            .run();
        assert!(r.completed);
        assert!(r.makespan_h < 10.0, "batch fleet must not wait for the horizon");
        let t = &r.tiers[0];
        assert!((t.ledger.time.get(Category::Useful) - 8.0).abs() < 1e-6);
        assert!(t.completed);
    }

    #[test]
    fn staggered_batch_completions_are_not_slo_violations() {
        let (w, start) = world();
        // one replica gets revoked and finishes late; the other's early
        // completion must not count the stagger as under-target time
        let spec = ServiceSpec::new("stagger")
            .horizon(200.0)
            .repack(false)
            .tier(TierSpec::batch("work", 2, 16.0, 6.0).slack(0.05));
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedCount { total: 1 })
            .start_t(start)
            .seed(6)
            .service(spec)
            .run();
        assert!(r.completed, "{r:?}");
        assert_eq!(r.revocations, 1);
        let t = &r.tiers[0];
        // only boots and the post-revocation gap may be under target
        assert!(
            t.slo_violation_h < 1.0,
            "stagger counted as violation: {} h over a {} h window",
            t.slo_violation_h,
            t.window_h
        );
    }

    #[test]
    fn revocations_trigger_fleet_repack() {
        let (w, start) = world();
        let spec = web(24.0).repack(true); // pin the full-drain oracle
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedRate { per_day: 12.0 })
            .start_t(start)
            .seed(5)
            .service(spec)
            .run();
        assert!(r.revocations > 0, "forced rate must revoke");
        assert_eq!(r.repacks, r.revocations, "every revocation consolidates the fleet");
        let total = r.ledger();
        assert!(total.time.get(Category::Repack) > 0.0, "survivors pay the transfer");
        assert!(total.cost.get(Category::Repack) > 0.0);
        // the fleet recovers: SLO damage is bounded by the prologue
        for t in &r.tiers {
            assert!(t.slo_violation_h < r.horizon_h * 0.5, "{}: {}", t.name, t.slo_violation_h);
        }
    }

    #[test]
    fn incremental_repack_counts_consolidations_without_transfer_charges() {
        let (w, start) = world();
        let spec = web(24.0); // repack defaults to Incremental
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedRate { per_day: 12.0 })
            .start_t(start)
            .seed(5)
            .service(spec)
            .run();
        assert!(r.revocations > 0, "forced rate must revoke");
        assert_eq!(r.repacks, r.revocations, "every revocation consolidates the fleet");
        // survivors are never drained: no state transfer anywhere
        assert_eq!(r.ledger().time.get(Category::Repack), 0.0);
        assert_eq!(r.ledger().cost.get(Category::Repack), 0.0);
        // warm-joins never overflow an instance
        assert!(r.peak_bin_used_gb <= r.capacity_gb + 1e-9, "{r:?}");
    }

    #[test]
    fn repack_modes_agree_without_revocations() {
        let (w, start) = world();
        let runs: Vec<ServiceResult> = [RepackMode::Off, RepackMode::Incremental, RepackMode::Full]
            .into_iter()
            .map(|mode| {
                Scenario::on(&w)
                    .policy(PolicyKind::OnDemand)
                    .start_t(start)
                    .seed(3)
                    .service(web(24.0).repack_mode(mode))
                    .run()
            })
            .collect();
        assert_eq!(runs[0], runs[1], "incremental must be invisible without revocations");
        assert_eq!(runs[0], runs[2], "full must be invisible without revocations");
    }

    #[test]
    fn repack_disabled_leaves_survivors_alone() {
        let (w, start) = world();
        let spec = web(24.0).repack(false);
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedCount { total: 2 })
            .start_t(start)
            .seed(7)
            .service(spec)
            .run();
        assert_eq!(r.revocations, 2);
        assert_eq!(r.repacks, 0);
        assert_eq!(r.ledger().time.get(Category::Repack), 0.0);
    }

    #[test]
    fn forced_count_fires_exactly_n() {
        let (w, start) = world();
        for &n in &[1u32, 2, 4] {
            let r = Scenario::on(&w)
                .policy(PolicyKind::FtSpot)
                .rule(RevocationRule::ForcedCount { total: n })
                .start_t(start)
                .seed(9)
                .service(web(24.0))
                .run();
            assert_eq!(r.revocations, n, "expected exactly {n} bin revocations");
        }
    }

    #[test]
    fn replication_copies_never_copacked_and_absorb_revocations() {
        let (w, start) = world();
        let spec = ServiceSpec::new("ha")
            .horizon(24.0)
            .capacity(64.0)
            .tier(TierSpec::open("core", 2, 8.0).slack(0.2));
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Replication { k: 2 })
            .rule(RevocationRule::ForcedRate { per_day: 8.0 })
            .start_t(start)
            .seed(11)
            .service(spec)
            .run();
        assert_eq!(r.copack_conflicts, 0, "grouped packing must separate copies");
        assert!(r.bins >= 2, "two copies need at least two bins");
        let t = &r.tiers[0];
        // standby capacity shows up as cost-only idle
        assert!(t.ledger.cost.get(Category::Idle) > 0.0);
        assert_eq!(t.ledger.time.get(Category::Idle), 0.0);
        if r.revocations > 0 {
            // absorbed: no recovery spans while a sibling lives
            assert!(t.slo_met, "replicated tier must hold its SLO: {t:?}");
        }
    }

    #[test]
    fn burst_schedule_scales_up_and_down() {
        let (w, start) = world();
        let spec = ServiceSpec::new("bursty")
            .horizon(40.0)
            .capacity(64.0)
            .repack(false)
            .tier(TierSpec::open("api", 2, 8.0).slack(0.2).burst(24.0, 6.0, 4));
        let r = Scenario::on(&w)
            .policy(PolicyKind::OnDemand)
            .start_t(start)
            .seed(2)
            .service(spec)
            .run();
        assert!(r.completed);
        let t = &r.tiers[0];
        // one burst window [start+24, start+30): 2 base replicas serve
        // ~40 h each, 2 burst extras ~6 h each, minus boots
        assert!(t.up_h > 2.0 * 38.0 + 2.0 * 4.0, "burst capacity missing: up {}", t.up_h);
        assert!(
            t.up_h < 2.0 * 40.0 + 2.0 * 6.5,
            "extras must retire at the window end: up {}",
            t.up_h
        );
        assert!(t.slo_met, "on-demand bursts should hold the SLO: {t:?}");
        assert!(r.bins > 1, "scale-ups launch fresh bins");
    }

    #[test]
    fn deterministic_per_seed() {
        let (w, start) = world();
        let scen = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedRate { per_day: 6.0 })
            .start_t(start)
            .service(web(24.0));
        let a = scen.run_seeded(42);
        let b = scen.run_seeded(42);
        assert_eq!(a, b);
    }

    #[test]
    fn replicate_matches_manual_loop_and_pool() {
        let (w, start) = world();
        let scen = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedCount { total: 1 })
            .start_t(start)
            .seed(11)
            .service(web(12.0));
        let agg = scen.replicate(3);
        assert_eq!(agg.n, 3);
        let manual: Vec<ServiceResult> = (11..14).map(|s| scen.run_seeded(s)).collect();
        assert_eq!(agg, ServiceAggregate::from_runs(&manual));
        let pooled = scen.replicate_on(&Pool::new(4), 3);
        assert_eq!(agg, pooled);
        assert_eq!(agg.tiers.len(), 2);
    }

    #[test]
    fn slo_violation_recorded_as_time_only_row() {
        let (w, start) = world();
        let r = Scenario::on(&w)
            .policy(PolicyKind::FtSpot)
            .rule(RevocationRule::ForcedRate { per_day: 24.0 })
            .start_t(start)
            .seed(4)
            .service(web(24.0))
            .run();
        for t in &r.tiers {
            assert!(
                (t.ledger.time.get(Category::Slo) - t.slo_violation_h).abs() < 1e-9,
                "slo row must mirror the integral"
            );
            assert_eq!(t.ledger.cost.get(Category::Slo), 0.0, "slo is never costed");
        }
    }
}
