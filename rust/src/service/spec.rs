//! The service workload model: long-running tiers that must keep a
//! target replica count online across revocations, with a deadline-slack
//! SLO instead of a completion deadline.
//!
//! Specs are buildable in code (`ServiceSpec::new("web").tier(...)`) or
//! parsed from the TOML subset `util::config` understands:
//!
//! ```toml
//! [service]
//! name = "web"
//! horizon_h = 72.0          # steady-state window simulated
//! capacity_gb = 64          # optional per-instance packing capacity
//! repack = "incremental"    # revocation response: "off", "incremental"
//!                           # (default: displaced replicas warm-join
//!                           # survivor headroom), or "full" (drain and
//!                           # re-pack the whole fleet — the oracle).
//!                           # Plain booleans still parse: true = "full",
//!                           # false = "off".
//!
//! [tier.frontend]
//! replicas = 4              # target replica count
//! mem_gb = 4.0
//! slack = 0.05              # SLO: fraction of the horizon the tier may
//!                           # run under target before the run violates
//! burst_every_h = 24.0      # optional periodic burst window ...
//! burst_len_h = 6.0         #   ... lasting this long ...
//! burst_replicas = 8        #   ... raising the target to this
//!
//! [tier.batch-reindex]
//! replicas = 2
//! mem_gb = 16.0
//! run_h = 6.0               # > 0 = batch tier: each replica owes this
//!                           # much work, then the tier is done
//! ```
//!
//! A tier without `run_h` is *open-ended*: its replicas serve until the
//! horizon and "useful work" is uptime.  A tier with `run_h` is a
//! *batch* tier riding in the same fleet; the whole run ends early when
//! every tier is batch and complete.  Tier order is declaration order
//! in code and sorted-by-name from TOML (deterministic, like
//! [`DagSpec`](crate::dag::DagSpec)).

use std::collections::BTreeSet;
use std::path::Path;

use crate::market::Catalog;
use crate::util::config::Config;

/// Periodic burst window raising a tier's target replica count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BurstSpec {
    /// window period (hours): bursts start at `start + k·every_h`
    pub every_h: f64,
    /// window length (hours), strictly less than the period
    pub len_h: f64,
    /// target replica count inside the window (> the base target)
    pub replicas: u32,
}

/// One tier of a service fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct TierSpec {
    /// Tier name (unique within the spec).
    pub name: String,
    /// target replica count outside burst windows
    pub replicas: u32,
    /// per-replica memory footprint (GB) — drives packing and shares
    pub mem_gb: f64,
    /// deadline-slack SLO: fraction of the tier's wall-clock it may run
    /// under target before the run counts as violated
    pub slack: f64,
    /// per-replica work budget (hours); `None` = open-ended service
    pub run_h: Option<f64>,
    /// optional periodic burst schedule (open-ended tiers only)
    pub burst: Option<BurstSpec>,
}

impl TierSpec {
    /// An open-ended tier (replicas serve until the horizon).
    pub fn open(name: impl Into<String>, replicas: u32, mem_gb: f64) -> TierSpec {
        TierSpec {
            name: name.into(),
            replicas,
            mem_gb,
            slack: 0.05,
            run_h: None,
            burst: None,
        }
    }

    /// A batch tier: each replica owes `run_h` hours of work.
    pub fn batch(name: impl Into<String>, replicas: u32, mem_gb: f64, run_h: f64) -> TierSpec {
        TierSpec { run_h: Some(run_h), ..TierSpec::open(name, replicas, mem_gb) }
    }

    /// Set the deadline-slack SLO fraction (builder style).
    pub fn slack(mut self, frac: f64) -> TierSpec {
        self.slack = frac;
        self
    }

    /// Attach a periodic burst window (builder style).
    pub fn burst(mut self, every_h: f64, len_h: f64, replicas: u32) -> TierSpec {
        self.burst = Some(BurstSpec { every_h, len_h, replicas });
        self
    }

    /// True when this tier has a finite work budget (batch semantics).
    pub fn is_batch(&self) -> bool {
        self.run_h.is_some()
    }

    /// Peak target replica count (burst window included).
    pub fn peak_replicas(&self) -> u32 {
        self.burst.map(|b| b.replicas).unwrap_or(0).max(self.replicas)
    }
}

/// How the fleet responds to a bin revocation (and burst boundary).
///
/// `Incremental` is the default: only the revoked bin's replicas move,
/// warm-joining residual headroom on surviving bins before falling back
/// to fresh launches — no survivor is disturbed and no `Repack`
/// transfer time is charged.  `Full` drains and re-packs the whole
/// fleet onto a fresh FFD packing (the consolidation oracle
/// `Incremental` is benchmarked against; also consolidates at burst
/// ends).  `Off` relaunches victims through the normal pack path and
/// never consolidates.  With zero revocations and zero bursts all
/// three modes produce bitwise-identical results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepackMode {
    /// never consolidate; victims relaunch via the normal pack path
    Off,
    /// move only displaced replicas, warm-joining survivor headroom
    Incremental,
    /// drain-and-repack oracle: every survivor moves on every event
    Full,
}

impl Default for RepackMode {
    fn default() -> RepackMode {
        RepackMode::Incremental
    }
}

impl RepackMode {
    /// The TOML spelling (also the CLI display label).
    pub fn as_str(self) -> &'static str {
        match self {
            RepackMode::Off => "off",
            RepackMode::Incremental => "incremental",
            RepackMode::Full => "full",
        }
    }
}

/// A validated-on-use service fleet of tiers.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceSpec {
    /// Service name (used in sweep rows and artifacts).
    pub name: String,
    /// steady-state window simulated (hours past the scenario start)
    pub horizon_h: f64,
    /// per-instance packing capacity override (GB); `None` = the
    /// largest instance type in the catalog
    pub capacity_gb: Option<f64>,
    /// revocation response: see [`RepackMode`]
    pub repack: RepackMode,
    /// The tiers making up the fleet.
    pub tiers: Vec<TierSpec>,
}

impl ServiceSpec {
    /// Start a spec named `name` (builder style).
    pub fn new(name: impl Into<String>) -> ServiceSpec {
        ServiceSpec {
            name: name.into(),
            horizon_h: 72.0,
            capacity_gb: None,
            repack: RepackMode::default(),
            tiers: Vec::new(),
        }
    }

    /// Append a tier (builder style).
    pub fn tier(mut self, tier: TierSpec) -> ServiceSpec {
        self.tiers.push(tier);
        self
    }

    /// Set the simulated horizon (hours).
    pub fn horizon(mut self, horizon_h: f64) -> ServiceSpec {
        self.horizon_h = horizon_h;
        self
    }

    /// Set the per-instance packing capacity (GB).
    pub fn capacity(mut self, capacity_gb: f64) -> ServiceSpec {
        self.capacity_gb = Some(capacity_gb);
        self
    }

    /// Boolean shorthand for [`ServiceSpec::repack_mode`], kept for
    /// call-site compatibility: `true` = the [`RepackMode::Full`]
    /// drain-and-repack oracle, `false` = [`RepackMode::Off`].
    pub fn repack(self, on: bool) -> ServiceSpec {
        self.repack_mode(if on { RepackMode::Full } else { RepackMode::Off })
    }

    /// Set the revocation response (builder style).
    pub fn repack_mode(mut self, mode: RepackMode) -> ServiceSpec {
        self.repack = mode;
        self
    }

    /// Number of tiers.
    pub fn len(&self) -> usize {
        self.tiers.len()
    }

    /// True when the spec holds no tiers.
    pub fn is_empty(&self) -> bool {
        self.tiers.is_empty()
    }

    /// Index of the tier named `name`, if present.
    pub fn tier_index(&self, name: &str) -> Option<usize> {
        self.tiers.iter().position(|t| t.name == name)
    }

    /// Base-target replica count across tiers (bursts excluded).
    pub fn total_replicas(&self) -> u32 {
        self.tiers.iter().map(|t| t.replicas).sum()
    }

    /// Largest per-replica memory footprint across tiers (GB).
    pub fn max_mem_gb(&self) -> f64 {
        self.tiers.iter().map(|t| t.mem_gb).fold(0.0, f64::max)
    }

    /// Every tier is a batch tier (the run can end before the horizon).
    pub fn is_batch_only(&self) -> bool {
        self.tiers.iter().all(TierSpec::is_batch)
    }

    /// Expected useful work over the horizon: batch tiers owe
    /// `replicas × run_h`, open-ended tiers serve `replicas × horizon`.
    /// The ForcedCount revocation rule spreads its thresholds over this
    /// total, mirroring the single-job rule over the job length.
    pub fn total_work_h(&self) -> f64 {
        self.tiers
            .iter()
            .map(|t| t.replicas as f64 * t.run_h.unwrap_or(self.horizon_h))
            .sum()
    }

    /// The packing capacity this spec gets against `catalog`: its
    /// `capacity_gb` (or the catalog default) clamped to the largest
    /// instance type.  Errors when a single replica exceeds the result;
    /// the one capacity rule shared by `FleetRunner` and the
    /// `siwoft service` CLI (same contract as
    /// [`DagSpec::effective_capacity`](crate::dag::DagSpec::effective_capacity)).
    pub fn effective_capacity(&self, catalog: &Catalog) -> Result<f64, String> {
        let cat_cap = catalog.markets.iter().map(|m| m.instance.mem_gb).fold(0.0f64, f64::max);
        let cap = self.capacity_gb.unwrap_or(cat_cap).min(cat_cap);
        if self.max_mem_gb() > cap {
            return Err(format!(
                "service '{}': replica footprint {} GB exceeds the instance capacity {} GB \
                 (largest type in a {}-market catalog)",
                self.name,
                self.max_mem_gb(),
                cap,
                catalog.len()
            ));
        }
        Ok(cap)
    }

    /// Validate the spec: non-empty, positive horizon, unique tier
    /// names, positive replica counts and footprints, sane SLO slack,
    /// positive batch budgets, and burst windows that fit their period,
    /// raise the target, and only decorate open-ended tiers.
    pub fn validate(&self) -> Result<(), String> {
        if self.tiers.is_empty() {
            return Err(format!("service '{}' has no tiers", self.name));
        }
        if !self.horizon_h.is_finite() || self.horizon_h <= 0.0 {
            return Err(format!("service '{}': horizon_h must be positive", self.name));
        }
        let mut seen = BTreeSet::new();
        for t in &self.tiers {
            if t.replicas == 0 {
                return Err(format!("tier '{}': replicas must be >= 1", t.name));
            }
            if t.mem_gb <= 0.0 {
                return Err(format!("tier '{}': mem_gb must be positive", t.name));
            }
            if !(0.0..=1.0).contains(&t.slack) {
                return Err(format!("tier '{}': slack must be in [0, 1]", t.name));
            }
            if let Some(r) = t.run_h {
                if r <= 0.0 {
                    return Err(format!("tier '{}': run_h must be positive", t.name));
                }
            }
            if !seen.insert(t.name.as_str()) {
                return Err(format!("duplicate tier name '{}'", t.name));
            }
            if let Some(b) = t.burst {
                if t.is_batch() {
                    return Err(format!(
                        "tier '{}': burst schedules apply to open-ended tiers only",
                        t.name
                    ));
                }
                if b.every_h <= 0.0 || b.len_h <= 0.0 || b.len_h >= b.every_h {
                    return Err(format!(
                        "tier '{}': burst window needs 0 < burst_len_h < burst_every_h",
                        t.name
                    ));
                }
                if b.replicas <= t.replicas {
                    return Err(format!(
                        "tier '{}': burst_replicas ({}) must exceed the base target ({})",
                        t.name, b.replicas, t.replicas
                    ));
                }
            }
        }
        if let Some(cap) = self.capacity_gb {
            if self.max_mem_gb() > cap {
                return Err(format!(
                    "service '{}': replica footprint {} GB exceeds capacity_gb {}",
                    self.name,
                    self.max_mem_gb(),
                    cap
                ));
            }
        }
        Ok(())
    }

    /// Parse a spec from the `[service]` + `[tier.<name>]` TOML layout.
    pub fn from_config(cfg: &Config) -> Result<ServiceSpec, String> {
        let name = cfg.str_or("service.name", "service").to_string();
        let horizon_h = cfg.f64_or("service.horizon_h", 72.0);
        let capacity_gb = cfg.get("service.capacity_gb").and_then(|v| v.as_f64());
        let repack = match cfg.get("service.repack") {
            None => RepackMode::default(),
            // legacy boolean form: true was the old always-repack
            // behavior (now the Full oracle), false disabled it
            Some(v) if v.as_bool() == Some(true) => RepackMode::Full,
            Some(v) if v.as_bool() == Some(false) => RepackMode::Off,
            Some(v) => match v.as_str() {
                Some("off") => RepackMode::Off,
                Some("incremental") => RepackMode::Incremental,
                Some("full") => RepackMode::Full,
                _ => {
                    return Err(format!(
                        "service '{name}': repack must be a bool or one of \
                         \"off\", \"incremental\", \"full\""
                    ))
                }
            },
        };
        // enumerate tier names from the key space (BTreeMap keys are
        // sorted, so TOML tier order is sorted-by-name — deterministic)
        let mut names: Vec<String> = Vec::new();
        for key in cfg.keys() {
            if let Some(rest) = key.strip_prefix("tier.") {
                if let Some((tier, _field)) = rest.split_once('.') {
                    if names.last().map(String::as_str) != Some(tier) {
                        names.push(tier.to_string());
                    }
                }
            }
        }
        names.dedup();
        if names.is_empty() {
            return Err(format!("service '{name}': no [tier.<name>] sections found"));
        }
        let mut tiers = Vec::with_capacity(names.len());
        for t in &names {
            let replicas = cfg.i64(&format!("tier.{t}.replicas")).map_err(|e| e.to_string())?;
            if replicas < 1 {
                return Err(format!("tier '{t}': replicas must be >= 1"));
            }
            let mem = cfg.f64(&format!("tier.{t}.mem_gb")).map_err(|e| e.to_string())?;
            let slack = cfg.f64_or(&format!("tier.{t}.slack"), 0.05);
            let run_h = match cfg.get(&format!("tier.{t}.run_h")) {
                None => None,
                Some(v) => {
                    let r = v
                        .as_f64()
                        .ok_or_else(|| format!("tier '{t}': run_h must be a number"))?;
                    if r <= 0.0 {
                        // match the builder path's validate() instead of
                        // silently demoting the tier to open-ended
                        return Err(format!("tier '{t}': run_h must be positive"));
                    }
                    Some(r)
                }
            };
            let burst = match cfg.get(&format!("tier.{t}.burst_every_h")) {
                None => None,
                Some(v) => {
                    let every_h = v
                        .as_f64()
                        .ok_or_else(|| format!("tier '{t}': burst_every_h must be a number"))?;
                    let len_h =
                        cfg.f64(&format!("tier.{t}.burst_len_h")).map_err(|e| e.to_string())?;
                    let replicas =
                        cfg.i64(&format!("tier.{t}.burst_replicas")).map_err(|e| e.to_string())?;
                    Some(BurstSpec { every_h, len_h, replicas: replicas.max(0) as u32 })
                }
            };
            tiers.push(TierSpec {
                name: t.clone(),
                replicas: replicas as u32,
                mem_gb: mem,
                slack,
                run_h,
                burst,
            });
        }
        let spec = ServiceSpec { name, horizon_h, capacity_gb, repack, tiers };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from TOML text.
    pub fn parse(text: &str) -> Result<ServiceSpec, String> {
        ServiceSpec::from_config(&Config::parse(text).map_err(|e| e.to_string())?)
    }

    /// Load a spec from a TOML file.
    pub fn load(path: impl AsRef<Path>) -> Result<ServiceSpec, String> {
        let path = path.as_ref();
        let cfg = Config::load(path).map_err(|e| format!("{}: {e}", path.display()))?;
        ServiceSpec::from_config(&cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn web() -> ServiceSpec {
        ServiceSpec::new("web")
            .horizon(48.0)
            .capacity(64.0)
            .tier(TierSpec::open("frontend", 4, 4.0).slack(0.1))
            .tier(TierSpec::open("api", 2, 8.0).burst(24.0, 6.0, 4))
            .tier(TierSpec::batch("reindex", 1, 16.0, 6.0))
    }

    #[test]
    fn builder_and_validate() {
        let s = web();
        assert!(s.validate().is_ok());
        assert_eq!(s.len(), 3);
        assert_eq!(s.total_replicas(), 7);
        assert_eq!(s.max_mem_gb(), 16.0);
        assert!(!s.is_batch_only());
        // open tiers owe replicas × horizon; the batch tier its budget
        assert!((s.total_work_h() - (4.0 * 48.0 + 2.0 * 48.0 + 6.0)).abs() < 1e-9);
        assert_eq!(s.tier_index("api"), Some(1));
        assert_eq!(s.tiers[1].peak_replicas(), 4);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(ServiceSpec::new("e").validate().unwrap_err().contains("no tiers"));
        let zero = ServiceSpec::new("z").tier(TierSpec::open("t", 0, 4.0));
        assert!(zero.validate().unwrap_err().contains("replicas"));
        let neg = ServiceSpec::new("n").tier(TierSpec::open("t", 1, -1.0));
        assert!(neg.validate().unwrap_err().contains("mem_gb"));
        let slack = ServiceSpec::new("s").tier(TierSpec::open("t", 1, 4.0).slack(1.5));
        assert!(slack.validate().unwrap_err().contains("slack"));
        let dup = ServiceSpec::new("d")
            .tier(TierSpec::open("t", 1, 4.0))
            .tier(TierSpec::open("t", 1, 4.0));
        assert!(dup.validate().unwrap_err().contains("duplicate"));
        let hz = ServiceSpec::new("h").horizon(0.0).tier(TierSpec::open("t", 1, 4.0));
        assert!(hz.validate().unwrap_err().contains("horizon"));
        let batch_burst = ServiceSpec::new("b")
            .tier(TierSpec::batch("t", 1, 4.0, 2.0).burst(24.0, 6.0, 3));
        assert!(batch_burst.validate().unwrap_err().contains("open-ended"));
        let wide = ServiceSpec::new("w").tier(TierSpec::open("t", 2, 4.0).burst(6.0, 6.0, 4));
        assert!(wide.validate().unwrap_err().contains("burst_len_h"));
        let flat = ServiceSpec::new("f").tier(TierSpec::open("t", 2, 4.0).burst(24.0, 6.0, 2));
        assert!(flat.validate().unwrap_err().contains("exceed"));
        let cap = ServiceSpec::new("c").capacity(8.0).tier(TierSpec::open("t", 1, 16.0));
        assert!(cap.validate().unwrap_err().contains("capacity_gb"));
    }

    #[test]
    fn effective_capacity_clamps_to_catalog() {
        let cat = Catalog::full(); // largest type: 192 GB
        assert_eq!(web().effective_capacity(&cat).unwrap(), 64.0);
        let uncapped = ServiceSpec::new("u").tier(TierSpec::open("t", 1, 8.0));
        assert_eq!(uncapped.effective_capacity(&cat).unwrap(), 192.0);
        let fantasy = ServiceSpec::new("x").capacity(10_000.0).tier(TierSpec::open("t", 1, 8.0));
        assert_eq!(fantasy.effective_capacity(&cat).unwrap(), 192.0);
        let tiny = Catalog::with_limit(1); // m5.large only: 8 GB
        assert!(web().effective_capacity(&tiny).unwrap_err().contains("exceeds"));
    }

    const TOML: &str = r#"
[service]
name = "web"
horizon_h = 48.0
capacity_gb = 64
repack = false

[tier.api]
replicas = 2
mem_gb = 8.0
burst_every_h = 24.0
burst_len_h = 6.0
burst_replicas = 4

[tier.frontend]
replicas = 4
mem_gb = 4.0
slack = 0.1

[tier.reindex]
replicas = 1
mem_gb = 16.0
run_h = 6.0
"#;

    #[test]
    fn parses_toml_layout() {
        let s = ServiceSpec::parse(TOML).unwrap();
        assert_eq!(s.name, "web");
        assert_eq!(s.horizon_h, 48.0);
        assert_eq!(s.capacity_gb, Some(64.0));
        // legacy boolean form: false maps to Off
        assert_eq!(s.repack, RepackMode::Off);
        assert_eq!(s.len(), 3);
        // sorted-by-name order from the config key space
        assert_eq!(s.tiers[0].name, "api");
        assert_eq!(s.tiers[0].burst, Some(BurstSpec { every_h: 24.0, len_h: 6.0, replicas: 4 }));
        assert_eq!(s.tiers[1].name, "frontend");
        assert_eq!(s.tiers[1].slack, 0.1);
        assert_eq!(s.tiers[2].run_h, Some(6.0));
        assert!(s.validate().is_ok());
    }

    #[test]
    fn toml_errors_are_friendly() {
        assert!(ServiceSpec::parse("[service]\nname = \"x\"\n")
            .unwrap_err()
            .contains("no [tier"));
        let missing = "[tier.a]\nmem_gb = 4.0\n";
        assert!(ServiceSpec::parse(missing).unwrap_err().contains("replicas"));
        let half_burst = "[tier.a]\nreplicas = 2\nmem_gb = 4.0\nburst_every_h = 24.0\n";
        assert!(ServiceSpec::parse(half_burst).unwrap_err().contains("burst_len_h"));
        // a non-positive run_h errors like the builder path instead of
        // silently becoming an open-ended tier
        let zero_run = "[tier.a]\nreplicas = 1\nmem_gb = 4.0\nrun_h = 0.0\n";
        assert!(ServiceSpec::parse(zero_run).unwrap_err().contains("run_h must be positive"));
        let neg_run = "[tier.a]\nreplicas = 1\nmem_gb = 4.0\nrun_h = -2.0\n";
        assert!(ServiceSpec::parse(neg_run).unwrap_err().contains("run_h must be positive"));
    }

    #[test]
    fn defaults_are_sane() {
        let s = ServiceSpec::parse("[tier.a]\nreplicas = 1\nmem_gb = 4.0\n").unwrap();
        assert_eq!(s.name, "service");
        assert_eq!(s.horizon_h, 72.0);
        assert_eq!(s.repack, RepackMode::Incremental);
        assert_eq!(s.tiers[0].slack, 0.05);
        assert_eq!(s.tiers[0].run_h, None);
    }

    #[test]
    fn repack_mode_parses_strings_and_booleans() {
        let tier = "[tier.a]\nreplicas = 1\nmem_gb = 4.0\n";
        let with = |v: &str| format!("[service]\nrepack = {v}\n{tier}");
        assert_eq!(ServiceSpec::parse(&with("\"off\"")).unwrap().repack, RepackMode::Off);
        assert_eq!(
            ServiceSpec::parse(&with("\"incremental\"")).unwrap().repack,
            RepackMode::Incremental
        );
        assert_eq!(ServiceSpec::parse(&with("\"full\"")).unwrap().repack, RepackMode::Full);
        assert_eq!(ServiceSpec::parse(&with("true")).unwrap().repack, RepackMode::Full);
        assert_eq!(ServiceSpec::parse(&with("false")).unwrap().repack, RepackMode::Off);
        assert!(ServiceSpec::parse(&with("\"sometimes\""))
            .unwrap_err()
            .contains("repack must be"));
        // builder shorthand maps the same way
        assert_eq!(ServiceSpec::new("b").repack(true).repack, RepackMode::Full);
        assert_eq!(ServiceSpec::new("b").repack(false).repack, RepackMode::Off);
        assert_eq!(ServiceSpec::new("b").repack, RepackMode::Incremental);
    }
}
