//! Fleet bookkeeping for service runs: per-tier results, uptime
//! interval algebra, the deadline-slack SLO integral, and the
//! seed-aggregation types the sweep layer consumes.
//!
//! The SLO model (DESIGN.md §10): a tier is *under target* at time `t`
//! when fewer logical replicas are up than `target(t)` demands (the
//! base target, raised inside burst windows).  A replica is up while it
//! is placed on an active instance and past its session prologue
//! (startup / recovery / re-pack transfer); with packed-bin
//! replication, a logical replica is up while *any* of its copies is.
//! The SLO-violation time is the integral of under-target wall-clock
//! over the tier's observation window, and the tier meets its SLO when
//! that integral stays within `slack × window`.

use crate::sim::accounting::{Breakdown, Category, Ledger};

use super::spec::TierSpec;

// ---------------------------------------------------------------------
// interval algebra

/// Merge possibly-overlapping `(start, end)` intervals into a sorted
/// disjoint union (used to collapse the k copies of a replicated
/// replica into one logical uptime timeline).
pub(crate) fn union_intervals(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.retain(|&(a, b)| b > a);
    iv.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap().then(x.1.partial_cmp(&y.1).unwrap()));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some((_, e)) if a <= *e + 1e-12 => *e = e.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Piecewise-constant target of `tier` over `[start, end)`: a sorted
/// list of `(time, target)` steps starting at `start`.  Burst windows
/// open at `start + k·every_h` for `k = 1, 2, …` (the first burst comes
/// one full period in, so a fresh fleet boots against the base target).
pub(crate) fn target_steps(tier: &TierSpec, start: f64, end: f64) -> Vec<(f64, u32)> {
    let mut steps = vec![(start, tier.replicas)];
    if let Some(b) = tier.burst {
        let mut k = 1u32;
        loop {
            let w0 = start + k as f64 * b.every_h;
            if w0 >= end {
                break;
            }
            steps.push((w0, b.replicas));
            let w1 = w0 + b.len_h;
            if w1 < end {
                steps.push((w1, tier.replicas));
            }
            k += 1;
        }
    }
    steps
}

/// Integral of under-target wall-clock over `[w0, w1)`: per-replica
/// uptime unions vs. the target steps, by midpoint sampling between
/// consecutive boundaries (robust to boundary coincidences; the
/// interval counts are small — sessions × replicas).
pub(crate) fn violation_time(
    replica_ups: &[Vec<(f64, f64)>],
    steps: &[(f64, u32)],
    w0: f64,
    w1: f64,
) -> f64 {
    if w1 <= w0 {
        return 0.0;
    }
    let mut bounds: Vec<f64> = vec![w0, w1];
    for ups in replica_ups {
        for &(a, b) in ups {
            if b > w0 && a < w1 {
                bounds.push(a.max(w0));
                bounds.push(b.min(w1));
            }
        }
    }
    for &(t, _) in steps {
        if t > w0 && t < w1 {
            bounds.push(t);
        }
    }
    bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    bounds.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    let mut viol = 0.0f64;
    for w in bounds.windows(2) {
        let (a, b) = (w[0], w[1]);
        let m = 0.5 * (a + b);
        let up = replica_ups
            .iter()
            .filter(|ups| ups.iter().any(|&(s, e)| s <= m && m < e))
            .count() as u32;
        let target = steps
            .iter()
            .rev()
            .find(|&&(t, _)| t <= m)
            .map(|&(_, n)| n)
            .unwrap_or(0);
        if up < target {
            viol += b - a;
        }
    }
    viol
}

// ---------------------------------------------------------------------
// results

/// Outcome of one tier across a whole service run.
#[derive(Clone, Debug, PartialEq)]
pub struct TierResult {
    /// Tier name (from the spec).
    pub name: String,
    /// merged replica ledgers; the time breakdown carries the tier's
    /// SLO-violation integral as the time-only [`Category::Slo`] row
    pub ledger: Ledger,
    /// wall-clock the tier spent under its target replica count
    pub slo_violation_h: f64,
    /// `slo_violation_h / window_h` — compared against the spec slack
    pub slo_frac: f64,
    /// the deadline-slack SLO held: `slo_frac <= slack`
    pub slo_met: bool,
    /// base target replica count
    pub target: u32,
    /// replica-hours of uptime accumulated over the window
    pub up_h: f64,
    /// observation window (horizon, or completion for batch tiers)
    pub window_h: f64,
    /// Instance revocations that hit this tier's replicas.
    pub revocations: u32,
    /// Replica sessions launched over the window.
    pub sessions: u32,
    /// re-pack moves of this tier's replicas (survivor migrations)
    pub repacks: u32,
    /// batch tiers: every replica finished its budget; open tiers: true
    pub completed: bool,
}

/// Outcome of one service fleet run.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceResult {
    /// Service scenario name.
    pub service: String,
    /// Provisioning policy that ran the fleet.
    pub policy: String,
    /// Fault-tolerance mechanism label (`"none"` under P-SIWOFT).
    pub ft: String,
    /// Per-tier outcomes, in spec order.
    pub tiers: Vec<TierResult>,
    /// wall-clock hours from start to fleet shutdown (the horizon, or
    /// earlier when every tier is batch and complete)
    pub makespan_h: f64,
    /// Nominal horizon of the run (hours).
    pub horizon_h: f64,
    /// instance revocation events (each kills a whole bin)
    pub revocations: u32,
    /// instance sessions launched (packed bins)
    pub bins: u32,
    /// fleet re-pack events (revocations / burst boundaries that
    /// triggered survivor consolidation)
    pub repacks: u32,
    /// Every batch tier finished its work budget.
    pub completed: bool,
    /// diagnostics pinned by `tests/properties.rs`
    pub capacity_gb: f64,
    /// Peak memory actually used in the fullest bin (GB).
    pub peak_bin_used_gb: f64,
    /// replicated copies that ended up co-packed (must stay 0 — the
    /// grouped packer forbids it)
    pub copack_conflicts: u32,
}

impl ServiceResult {
    /// Total deployment cost across tiers ($).
    pub fn cost_usd(&self) -> f64 {
        self.tiers.iter().map(|t| t.ledger.cost_usd()).sum()
    }

    /// All tier ledgers merged (per-category totals).
    pub fn ledger(&self) -> Ledger {
        let mut out = Ledger::new();
        for t in &self.tiers {
            out.merge(&t.ledger);
        }
        out
    }

    /// The tier outcome named `name`, if present.
    pub fn tier(&self, name: &str) -> Option<&TierResult> {
        self.tiers.iter().find(|t| t.name == name)
    }

    /// Every tier held its deadline-slack SLO.
    pub fn slo_met(&self) -> bool {
        self.tiers.iter().all(|t| t.slo_met)
    }

    /// Total re-pack transfer cost across tiers ($).
    pub fn repack_cost_usd(&self) -> f64 {
        self.tiers.iter().map(|t| t.ledger.cost.get(Category::Repack)).sum()
    }
}

/// Per-tier means over a set of service runs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TierAgg {
    /// Tier name (from the spec).
    pub name: String,
    /// Mean per-category time breakdown (hours).
    pub time: Breakdown,
    /// Mean per-category cost breakdown ($).
    pub cost: Breakdown,
    /// Mean wall-clock under target replica count (hours).
    pub mean_slo_violation_h: f64,
    /// Mean replica-hours of uptime.
    pub mean_up_h: f64,
    /// Fraction of runs where this tier held its SLO.
    pub slo_met_rate: f64,
    /// Mean revocations hitting this tier.
    pub mean_revocations: f64,
    /// Mean replica sessions launched.
    pub mean_sessions: f64,
    /// Mean survivor re-pack moves.
    pub mean_repacks: f64,
    /// Fraction of runs where this tier completed its budget.
    pub completion_rate: f64,
}

/// Mean fleet outcome over seeds (one "bar" of a service sweep).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceAggregate {
    /// Number of runs aggregated.
    pub n: usize,
    /// Mean wall-clock from start to fleet shutdown (hours).
    pub mean_makespan_h: f64,
    /// Mean total deployment cost ($).
    pub mean_cost_usd: f64,
    /// Mean instance revocation events.
    pub mean_revocations: f64,
    /// Mean instance sessions (packed bins) launched.
    pub mean_bins: f64,
    /// Mean fleet re-pack events.
    pub mean_repacks: f64,
    /// fraction of runs where every tier held its SLO
    pub slo_met_rate: f64,
    /// Fraction of runs where every batch tier completed.
    pub completion_rate: f64,
    /// Per-tier means, in spec order.
    pub tiers: Vec<TierAgg>,
}

impl ServiceAggregate {
    /// Aggregate a set of runs (empty input → all-zero default).
    pub fn from_runs(runs: &[ServiceResult]) -> ServiceAggregate {
        if runs.is_empty() {
            return ServiceAggregate::default();
        }
        let n = runs.len();
        let nf = n as f64;
        let n_tiers = runs[0].tiers.len();
        let mut tiers = Vec::with_capacity(n_tiers);
        for ti in 0..n_tiers {
            let mut agg = TierAgg { name: runs[0].tiers[ti].name.clone(), ..Default::default() };
            for r in runs {
                let t = &r.tiers[ti];
                agg.time.merge(&t.ledger.time);
                agg.cost.merge(&t.ledger.cost);
                agg.mean_slo_violation_h += t.slo_violation_h;
                agg.mean_up_h += t.up_h;
                agg.slo_met_rate += t.slo_met as usize as f64;
                agg.mean_revocations += t.revocations as f64;
                agg.mean_sessions += t.sessions as f64;
                agg.mean_repacks += t.repacks as f64;
                agg.completion_rate += t.completed as usize as f64;
            }
            agg.time = agg.time.scale(1.0 / nf);
            agg.cost = agg.cost.scale(1.0 / nf);
            agg.mean_slo_violation_h /= nf;
            agg.mean_up_h /= nf;
            agg.slo_met_rate /= nf;
            agg.mean_revocations /= nf;
            agg.mean_sessions /= nf;
            agg.mean_repacks /= nf;
            agg.completion_rate /= nf;
            tiers.push(agg);
        }
        ServiceAggregate {
            n,
            mean_makespan_h: runs.iter().map(|r| r.makespan_h).sum::<f64>() / nf,
            mean_cost_usd: runs.iter().map(|r| r.cost_usd()).sum::<f64>() / nf,
            mean_revocations: runs.iter().map(|r| r.revocations as f64).sum::<f64>() / nf,
            mean_bins: runs.iter().map(|r| r.bins as f64).sum::<f64>() / nf,
            mean_repacks: runs.iter().map(|r| r.repacks as f64).sum::<f64>() / nf,
            slo_met_rate: runs.iter().filter(|r| r.slo_met()).count() as f64 / nf,
            completion_rate: runs.iter().filter(|r| r.completed).count() as f64 / nf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::spec::TierSpec;

    #[test]
    fn union_merges_overlaps() {
        let u = union_intervals(vec![(3.0, 4.0), (0.0, 1.0), (0.5, 2.0), (4.0, 5.0)]);
        assert_eq!(u, vec![(0.0, 2.0), (3.0, 5.0)]);
        assert!(union_intervals(vec![(1.0, 1.0)]).is_empty());
        assert!(union_intervals(Vec::new()).is_empty());
    }

    #[test]
    fn target_steps_open_periodic_windows() {
        let t = TierSpec::open("t", 2, 4.0).burst(10.0, 2.0, 5);
        let steps = target_steps(&t, 100.0, 125.0);
        assert_eq!(steps, vec![
            (100.0, 2),
            (110.0, 5),
            (112.0, 2),
            (120.0, 5),
            (122.0, 2),
        ]);
        // burstless tier: one flat step
        let flat = TierSpec::open("f", 3, 4.0);
        assert_eq!(target_steps(&flat, 0.0, 50.0), vec![(0.0, 3)]);
    }

    #[test]
    fn violation_integral_counts_under_target_time() {
        // two replicas, target 2 over [0, 10): replica 0 up [1, 10),
        // replica 1 up [1, 4) and [6, 10) → under target on [0,1) and [4,6)
        let ups = vec![vec![(1.0, 10.0)], vec![(1.0, 4.0), (6.0, 10.0)]];
        let steps = vec![(0.0, 2u32)];
        let v = violation_time(&ups, &steps, 0.0, 10.0);
        assert!((v - 3.0).abs() < 1e-9, "violation {v}");
        // dropping the target to 1 leaves only the boot hour
        let v1 = violation_time(&ups, &[(0.0, 1)], 0.0, 10.0);
        assert!((v1 - 1.0).abs() < 1e-9);
        // a burst the fleet ignores is pure violation
        let v2 = violation_time(&ups, &[(0.0, 2), (4.0, 3), (6.0, 2)], 0.0, 10.0);
        assert!((v2 - 5.0).abs() < 1e-9, "violation {v2}");
    }

    #[test]
    fn aggregate_over_empty_is_default() {
        assert_eq!(ServiceAggregate::from_runs(&[]), ServiceAggregate::default());
    }
}
