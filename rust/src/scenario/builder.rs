//! The `Scenario` builder: one fully-specified simulation point.
//!
//! A scenario is the unit every experiment in the paper is made of —
//! *this* job, under *this* policy and FT mechanism, revoked by *this*
//! rule, starting at *this* trace offset, with *this* seed.  The
//! builder owns the construction that call sites used to hand-roll
//! (policy/FT instantiation, `RunConfig` literals, seed-replication
//! loops) and funnels everything into the one session-simulator engine
//! in `sim::run`.

use std::sync::OnceLock;

use super::registry::{FtKind, PolicyKind};
use crate::coordinator::Pool;
use crate::job::Job;
use crate::market::analytics::SurvivalCurves;
use crate::policy::{Policy, PredictivePolicy};
use crate::sim::run::execute_in;
use crate::sim::{AggregateResult, JobResult, RevocationRule, RunConfig, Scratch, World};

/// A fully-specified simulation point, ready to run or replicate.
///
/// Defaults: the paper's fixed job point (8 h / 16 GB), P-SIWOFT with
/// no FT mechanism, trace-driven revocations, trace start 0, seed 0.
#[derive(Clone, Debug)]
pub struct Scenario<'w> {
    world: &'w World,
    job: Job,
    policy: PolicyKind,
    ft: FtKind,
    cfg: RunConfig,
    seed: u64,
    /// `Predictive` training is a pure function of (world, start_t), so
    /// replicates share one fit instead of retraining per seed; the
    /// `start_t`/`config` setters invalidate it.
    curves: OnceLock<SurvivalCurves>,
}

impl<'w> Scenario<'w> {
    /// Start building a scenario against `world`.
    pub fn on(world: &'w World) -> Scenario<'w> {
        Scenario {
            world,
            job: Job::new(0, 8.0, 16.0),
            policy: PolicyKind::default(),
            ft: FtKind::default(),
            cfg: RunConfig::default(),
            seed: 0,
            curves: OnceLock::new(),
        }
    }

    /// The job to provision.
    pub fn job(mut self, job: Job) -> Self {
        self.job = job;
        self
    }

    /// The provisioning policy to run.
    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }

    /// The fault-tolerance mechanism to pair with it.
    pub fn ft(mut self, ft: FtKind) -> Self {
        self.ft = ft;
        self
    }

    /// The revocation arrival rule.
    pub fn rule(mut self, rule: RevocationRule) -> Self {
        self.cfg.rule = rule;
        self
    }

    /// Simulation start hour within the trace window.
    pub fn start_t(mut self, start_t: f64) -> Self {
        if self.cfg.start_t != start_t {
            self.curves = OnceLock::new();
        }
        self.cfg.start_t = start_t;
        self
    }

    /// Safety valve: abort after this many sessions (marks `!completed`).
    pub fn max_sessions(mut self, max_sessions: u32) -> Self {
        self.cfg.max_sessions = max_sessions;
        self
    }

    /// Replace the whole run configuration at once (rule + start +
    /// session cap).
    pub fn config(mut self, cfg: RunConfig) -> Self {
        if self.cfg.start_t != cfg.start_t {
            self.curves = OnceLock::new();
        }
        self.cfg = cfg;
        self
    }

    /// The RNG seed for this run.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pre-seed the survival-curve cache with an already-trained fit
    /// (used by `Sweep` to share one fit across every point of a
    /// sweep — they all see the same world and start).  No-op if the
    /// cache is already populated.
    pub(crate) fn with_curves(self, curves: SurvivalCurves) -> Self {
        let _ = self.curves.set(curves);
        self
    }

    // -- accessors (used by sweeps and result labelling) ---------------

    /// The world this scenario runs in.
    pub fn world(&self) -> &'w World {
        self.world
    }
    /// The configured job.
    pub fn job_ref(&self) -> &Job {
        &self.job
    }
    /// The configured policy kind.
    pub fn policy_kind(&self) -> PolicyKind {
        self.policy
    }
    /// The configured fault-tolerance kind.
    pub fn ft_kind(&self) -> FtKind {
        self.ft
    }
    /// The [`RunConfig`] this scenario will execute with.
    pub fn run_config(&self) -> RunConfig {
        self.cfg
    }

    /// The configured RNG seed.
    pub fn seed_value(&self) -> u64 {
        self.seed
    }

    /// Attach a DAG workload: the scenario's policy/FT/rule/start/seed
    /// settings drive a [`DagRunner`](crate::dag::DagRunner) over `spec`
    /// instead of the single-job session simulator.  Panics if `spec`
    /// fails [`DagSpec::validate`](crate::dag::DagSpec::validate).
    pub fn dag(self, spec: crate::dag::DagSpec) -> crate::dag::DagScenario<'w> {
        crate::dag::DagScenario::from_scenario(self, spec)
    }

    /// Attach a service fleet: the scenario's policy/FT/rule/start/seed
    /// settings drive a [`FleetRunner`](crate::service::FleetRunner)
    /// over `spec` in a horizon-bounded steady-state loop.  Panics if
    /// `spec` fails
    /// [`ServiceSpec::validate`](crate::service::ServiceSpec::validate).
    pub fn service(self, spec: crate::service::ServiceSpec) -> crate::service::ServiceScenario<'w> {
        crate::service::ServiceScenario::from_scenario(self, spec)
    }

    /// Instantiate the policy for one run.  `Predictive` shares one
    /// survival-curve fit across every seed of this point (the fit
    /// ignores the seed); `get_or_init` also makes concurrent pool
    /// workers wait for one training run.
    pub(crate) fn build_policy(&self) -> Box<dyn Policy> {
        match self.policy {
            PolicyKind::Predictive(cfg) => {
                let curves = self.curves.get_or_init(|| {
                    PolicyKind::train_survival_curves(self.world, self.cfg.start_t)
                });
                Box::new(PredictivePolicy::new(curves.clone(), cfg))
            }
            kind => kind.build(self.world, self.cfg.start_t),
        }
    }

    /// Run the scenario once with its configured seed.
    pub fn run(&self) -> JobResult {
        self.run_seeded(self.seed)
    }

    /// Run the scenario once with an explicit seed (the configured seed
    /// is ignored; everything else is reused).
    pub fn run_seeded(&self, seed: u64) -> JobResult {
        self.run_seeded_in(&mut Scratch::new(), seed)
    }

    /// [`Scenario::run_seeded`] with caller-owned working memory: a
    /// sweep worker passes its per-thread [`Scratch`] so consecutive
    /// runs reuse buffer capacity instead of re-allocating.  Identical
    /// results for any scratch state (pinned by
    /// `tests/engine_equivalence.rs`).
    pub fn run_seeded_in(&self, scratch: &mut Scratch, seed: u64) -> JobResult {
        let mut policy = self.build_policy();
        // Emitted per run, not from inside the `OnceLock` fit: which run
        // races the training first is worker-dependent, but every
        // Predictive run *consumes* a trained state, so per-run emission
        // is worker-count invariant.
        if matches!(self.policy, PolicyKind::Predictive(_)) {
            scratch.trace.emit(
                self.cfg.start_t,
                crate::obs::TraceEvent::SessionTrain { markets: self.world.n_markets() as u64 },
            );
        }
        let ft = self.ft.build(&self.job);
        execute_in(self.world, policy.as_mut(), ft.as_ref(), &self.job, &self.cfg, seed, scratch)
    }

    /// Run `n_seeds` replicates (seeds `seed .. seed + n_seeds`),
    /// serially, aggregated into one figure bar.
    pub fn replicate(&self, n_seeds: u64) -> AggregateResult {
        let runs: Vec<JobResult> = (0..n_seeds).map(|i| self.run_seeded(self.seed + i)).collect();
        AggregateResult::from_runs(&runs)
    }

    /// Like [`Scenario::replicate`] but fanned out over `pool`.
    /// `Pool::map` preserves submission order and each run is a pure
    /// function of its seed, so the aggregate is identical for any
    /// worker count.
    pub fn replicate_on(&self, pool: &Pool, n_seeds: u64) -> AggregateResult {
        let runs: Vec<JobResult> = pool.map_with(
            (0..n_seeds).collect(),
            1,
            Scratch::new,
            |scratch, _, i| self.run_seeded_in(scratch, self.seed + i),
        );
        AggregateResult::from_runs(&runs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Category;

    fn world() -> World {
        World::generate(48, 1.0, 11)
    }

    #[test]
    fn run_defaults_complete() {
        let w = world();
        let r = Scenario::on(&w).job(Job::new(1, 4.0, 16.0)).seed(2).run();
        assert!(r.completed);
        assert!((r.ledger.time.get(Category::Useful) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn replicate_matches_manual_seed_loop() {
        let w = world();
        let scen = Scenario::on(&w)
            .job(Job::new(2, 3.0, 16.0))
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::Checkpoint { n: 3 })
            .rule(RevocationRule::ForcedRate { per_day: 4.0 })
            .seed(5);
        let agg = scen.replicate(4);
        assert_eq!(agg.n, 4);
        let manual: Vec<JobResult> = (5..9).map(|s| scen.run_seeded(s)).collect();
        let manual_agg = AggregateResult::from_runs(&manual);
        assert_eq!(agg, manual_agg);
    }

    #[test]
    fn replicate_on_pool_matches_serial() {
        let w = world();
        let scen = Scenario::on(&w)
            .job(Job::new(3, 3.0, 16.0))
            .policy(PolicyKind::FtSpot)
            .ft(FtKind::CheckpointHourly)
            .rule(RevocationRule::ForcedCount { total: 2 });
        let serial = scen.replicate(6);
        let pooled = scen.replicate_on(&Pool::new(4), 6);
        assert_eq!(serial, pooled);
    }

    #[test]
    fn config_setters_land_in_run_config() {
        let w = world();
        let scen = Scenario::on(&w)
            .rule(RevocationRule::ForcedCount { total: 3 })
            .start_t(12.5)
            .max_sessions(77);
        let cfg = scen.run_config();
        assert_eq!(cfg.rule, RevocationRule::ForcedCount { total: 3 });
        assert_eq!(cfg.start_t, 12.5);
        assert_eq!(cfg.max_sessions, 77);
    }
}
