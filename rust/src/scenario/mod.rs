//! The scenario layer — the single public entry point for running
//! simulations.
//!
//! The paper's entire evaluation (§IV, Fig. 1a–1f, the tables) is a
//! cartesian product of scenarios: (policy × FT mechanism × revocation
//! rule × job × seeds).  This module gives that product a first-class
//! API so experiment drivers, the CLI, the TOML configs, and tests all
//! construct runs the same way:
//!
//! * [`registry`] — the [`PolicyKind`] / [`FtKind`] declarative enums
//!   with `parse()` (string names from CLI/TOML) and `build()`
//!   (instantiate the trait object) factories;
//! * [`builder`] — the [`Scenario`] builder: one (world, job, policy,
//!   ft, rule, seed) point with `.run()` and `.replicate(n)`;
//! * [`sweep`] — the [`Sweep`] type: axes of policies/fts/rules/jobs
//!   fanned out over [`coordinator::Pool`](crate::coordinator::Pool)
//!   with a `workers` knob.
//!
//! ```no_run
//! use siwoft::prelude::*;
//!
//! let mut world = World::generate(96, 2.0, 7);
//! let start = world.split_train(0.67);
//! let r = Scenario::on(&world)
//!     .job(Job::new(1, 8.0, 16.0))
//!     .policy(PolicyKind::default())      // P-SIWOFT
//!     .ft(FtKind::None)
//!     .rule(RevocationRule::Trace)
//!     .start_t(start)
//!     .seed(7)
//!     .run();
//! assert!(r.completed);
//! ```
//!
//! The legacy free function `sim::simulate_job` remains as a
//! `#[deprecated]` shim; `tests/scenario_equivalence.rs` proves the
//! builder path is bit-identical to it across the full
//! (policy × ft × rule) grid.

pub mod builder;
pub mod registry;
pub mod sweep;

pub use builder::Scenario;
pub use registry::{FtKind, PolicyKind};
pub use sweep::{DagSweepRow, ServiceSweepRow, Sweep, SweepPoint, SweepRow};
