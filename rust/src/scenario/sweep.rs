//! The `Sweep` type: a cartesian product of scenario axes fanned out
//! over the worker pool.
//!
//! A sweep is what every figure panel and ablation series really is —
//! (jobs × policies × fts × rules), each point replicated over `seeds`
//! randomized runs.  Points are enumerated in a fixed order (jobs
//! outermost, rules innermost) and executed at (point × seed)
//! granularity through [`Pool::map`], which preserves submission order;
//! results are therefore identical for any `workers` setting.

use std::sync::Arc;

use super::builder::Scenario;
use super::registry::{FtKind, PolicyKind};
use crate::coordinator::Pool;
use crate::dag::{DagAggregate, DagResult, DagScenario, DagSpec};
use crate::job::Job;
use crate::obs::{Collector, TraceSink};
use crate::service::{ServiceAggregate, ServiceResult, ServiceScenario, ServiceSpec};
use crate::market::analytics::SurvivalCurves;
use crate::sim::{AggregateResult, JobResult, RevocationRule, Scratch, World};

/// One point of the cartesian product.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepPoint {
    /// The job being provisioned.
    pub job: Job,
    /// The provisioning policy under test.
    pub policy: PolicyKind,
    /// The fault-tolerance mechanism paired with it.
    pub ft: FtKind,
    /// The revocation arrival rule.
    pub rule: RevocationRule,
}

/// One executed point: the aggregate bar plus the per-seed runs behind
/// it (seed `i` of the row is `base_seed + i`).
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// The point this row executed.
    pub point: SweepPoint,
    /// The aggregate over all seeds (the plotted bar).
    pub agg: AggregateResult,
    /// The per-seed runs behind the aggregate.
    pub runs: Vec<JobResult>,
}

/// Axes of a scenario sweep.
///
/// Defaults: no jobs (the one axis with no sensible default), P-SIWOFT
/// only, no FT, trace-driven revocations, 1 seed, trace start 0,
/// `workers = 0` (one per CPU core).
#[derive(Clone, Debug)]
pub struct Sweep<'w> {
    world: &'w World,
    jobs: Vec<Job>,
    dags: Vec<DagSpec>,
    services: Vec<ServiceSpec>,
    policies: Vec<PolicyKind>,
    fts: Vec<FtKind>,
    rules: Vec<RevocationRule>,
    seeds: u64,
    base_seed: u64,
    start_t: f64,
    max_sessions: u32,
    workers: usize,
    curves: Option<SurvivalCurves>,
    trace: Option<Arc<Collector>>,
}

impl<'w> Sweep<'w> {
    /// Start building a sweep over `world` (builder style).
    pub fn on(world: &'w World) -> Sweep<'w> {
        Sweep {
            world,
            jobs: Vec::new(),
            dags: Vec::new(),
            services: Vec::new(),
            policies: vec![PolicyKind::default()],
            fts: vec![FtKind::default()],
            rules: vec![RevocationRule::Trace],
            seeds: 1,
            base_seed: 0,
            start_t: 0.0,
            max_sessions: crate::sim::RunConfig::default().max_sessions,
            workers: 0,
            curves: None,
            trace: None,
        }
    }

    /// Add one job to the job axis.
    pub fn job(mut self, job: Job) -> Self {
        self.jobs.push(job);
        self
    }

    /// Replace the job axis.
    pub fn jobs(mut self, jobs: impl IntoIterator<Item = Job>) -> Self {
        self.jobs = jobs.into_iter().collect();
        self
    }

    /// Add one DAG to the DAG axis (consumed by [`Sweep::run_dags`]).
    pub fn dag(mut self, spec: DagSpec) -> Self {
        self.dags.push(spec);
        self
    }

    /// Replace the DAG axis.
    pub fn dags(mut self, specs: impl IntoIterator<Item = DagSpec>) -> Self {
        self.dags = specs.into_iter().collect();
        self
    }

    /// Add one service fleet to the service axis (consumed by
    /// [`Sweep::run_services`]).
    pub fn service(mut self, spec: ServiceSpec) -> Self {
        self.services.push(spec);
        self
    }

    /// Replace the service axis.
    pub fn services(mut self, specs: impl IntoIterator<Item = ServiceSpec>) -> Self {
        self.services = specs.into_iter().collect();
        self
    }

    /// The policy axis of the cartesian product.
    pub fn policies(mut self, policies: impl IntoIterator<Item = PolicyKind>) -> Self {
        self.policies = policies.into_iter().collect();
        self
    }

    /// The fault-tolerance axis of the cartesian product.
    pub fn fts(mut self, fts: impl IntoIterator<Item = FtKind>) -> Self {
        self.fts = fts.into_iter().collect();
        self
    }

    /// The revocation-rule axis of the cartesian product.
    pub fn rules(mut self, rules: impl IntoIterator<Item = RevocationRule>) -> Self {
        self.rules = rules.into_iter().collect();
        self
    }

    /// Randomized replicates per point (seeds `base_seed .. base_seed + n`).
    pub fn seeds(mut self, n: u64) -> Self {
        self.seeds = n.max(1);
        self
    }

    /// First seed of each point's replicate range.
    pub fn base_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// Submission time for every job (absolute sim hours).
    pub fn start_t(mut self, start_t: f64) -> Self {
        self.start_t = start_t;
        self
    }

    /// Session cap per run (0 = unlimited).
    pub fn max_sessions(mut self, max_sessions: u32) -> Self {
        self.max_sessions = max_sessions;
        self
    }

    /// Worker threads for the fan-out (0 = one per available CPU).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Inject a pre-trained Predictive survival-curve fit instead of
    /// training one in [`Sweep::run`].  The caller vouches that the fit
    /// came from `PolicyKind::train_survival_curves` (or an equivalent
    /// computation) over this sweep's world and `start_t` — the session
    /// subsystem (DESIGN.md §14) uses this to reuse a session's cached
    /// state across submits with bit-identical results.
    pub fn curves(mut self, curves: SurvivalCurves) -> Self {
        self.curves = Some(curves);
        self
    }

    /// Collect structured traces into `collector` (DESIGN.md §15).
    /// Each run is keyed `(run, seed, ord)` where `run` is the
    /// deterministic global run index `point_index * seeds +
    /// seed_offset`, so the collector's sorted output is byte-identical
    /// for any `workers` setting (pinned by `tests/obs_equivalence.rs`).
    /// Off by default — a trace-less sweep pays one branch per would-be
    /// event.
    pub fn trace(mut self, collector: Arc<Collector>) -> Self {
        self.trace = Some(collector);
        self
    }

    /// Arm a worker's sink for one (point, seed) run; no-op when
    /// tracing is off.
    fn arm_trace(&self, scratch: &mut Scratch, pi: usize, s: u64) {
        if let Some(col) = &self.trace {
            if !scratch.trace.is_on() {
                scratch.trace = TraceSink::to(col.clone());
            }
            scratch.trace.begin_run(pi as u64 * self.seeds + s, self.base_seed + s);
        }
    }

    /// The cartesian product, in execution order: jobs × policies × fts
    /// × rules (rules vary fastest).
    pub fn points(&self) -> Vec<SweepPoint> {
        let mut out =
            Vec::with_capacity(self.jobs.len() * self.policies.len() * self.fts.len() * self.rules.len());
        for job in &self.jobs {
            for &policy in &self.policies {
                for &ft in &self.fts {
                    for &rule in &self.rules {
                        out.push(SweepPoint { job: job.clone(), policy, ft, rule });
                    }
                }
            }
        }
        out
    }

    /// Number of sweep points — the rows [`Sweep::run`] returns.
    pub fn len(&self) -> usize {
        self.jobs.len() * self.policies.len() * self.fts.len() * self.rules.len()
    }

    /// True when the cartesian product is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total simulated runs: points × seeds.
    pub fn total_runs(&self) -> usize {
        self.len() * self.seeds as usize
    }

    /// The Predictive fit shared across every point that needs one:
    /// the injected [`Sweep::curves`] override when present, else a
    /// fresh fit over (world, start_t) — both sweep-wide constants, so
    /// training happens at most once per run.  `None` when no policy on
    /// the axis is Predictive.
    fn shared_curves(&self) -> Option<SurvivalCurves> {
        if !self.policies.iter().any(|p| matches!(p, PolicyKind::Predictive(_))) {
            return None;
        }
        Some(match &self.curves {
            Some(c) => c.clone(),
            None => PolicyKind::train_survival_curves(self.world, self.start_t),
        })
    }

    /// Execute the sweep: every (point, seed) pair fanned out over the
    /// pool, grouped back into one aggregated row per point.
    pub fn run(&self) -> Vec<SweepRow> {
        let points = self.points();
        if points.is_empty() {
            return Vec::new();
        }
        let seeds = self.seeds;
        // The Predictive fit depends only on (world, start_t) — both
        // sweep-wide constants — so train at most once and share the
        // result across every point that needs it.
        let shared_curves = self.shared_curves();
        // one Scenario per point, shared across its seeds, so per-point
        // state (the pre-seeded curve cache) is never recomputed
        let scenarios: Vec<Scenario<'_>> = points
            .iter()
            .map(|point| {
                let scen = Scenario::on(self.world)
                    .job(point.job.clone())
                    .policy(point.policy)
                    .ft(point.ft)
                    .rule(point.rule)
                    .start_t(self.start_t)
                    .max_sessions(self.max_sessions);
                match (&point.policy, &shared_curves) {
                    (PolicyKind::Predictive(_), Some(curves)) => scen.with_curves(curves.clone()),
                    _ => scen,
                }
            })
            .collect();
        let items: Vec<(usize, u64)> = (0..points.len())
            .flat_map(|p| (0..seeds).map(move |s| (p, s)))
            .collect();
        let pool = Pool::new(self.workers);
        // chunk hint 1: every (point, seed) run is milliseconds-scale
        // with wildly skewed costs, so each must be independently
        // stealable for nested grids to saturate many-core hosts.
        // Each worker reuses one Scratch across every run it steals,
        // so segment timelines stop re-allocating per (point × seed).
        let runs: Vec<JobResult> = pool.map_with(items, 1, Scratch::new, |scratch, _, (pi, s)| {
            self.arm_trace(scratch, pi, s);
            scenarios[pi].run_seeded_in(scratch, self.base_seed + s)
        });
        runs.chunks(seeds as usize)
            .zip(points)
            .map(|(chunk, point)| SweepRow {
                point,
                agg: AggregateResult::from_runs(chunk),
                runs: chunk.to_vec(),
            })
            .collect()
    }

    /// Execute the DAG axis: (dags × policies × fts × rules) × seeds,
    /// fanned out over the pool at per-run steal granularity via
    /// `map_chunked` (DAG runs are the most skewed items the scheduler
    /// sees — a revocation-heavy run costs many times a clean one).
    /// Rows follow the same fixed enumeration as [`Sweep::run`] (dags
    /// outermost, rules innermost), so results are identical for any
    /// `workers` setting.
    pub fn run_dags(&self) -> Vec<DagSweepRow> {
        if self.dags.is_empty() {
            return Vec::new();
        }
        let seeds = self.seeds;
        let shared_curves = self.shared_curves();
        let mut labels = Vec::new();
        let mut scenarios: Vec<DagScenario<'_>> = Vec::new();
        for spec in &self.dags {
            for &policy in &self.policies {
                for &ft in &self.fts {
                    for &rule in &self.rules {
                        let scen = Scenario::on(self.world)
                            .policy(policy)
                            .ft(ft)
                            .rule(rule)
                            .start_t(self.start_t)
                            .max_sessions(self.max_sessions);
                        let scen = match (&policy, &shared_curves) {
                            (PolicyKind::Predictive(_), Some(curves)) => {
                                scen.with_curves(curves.clone())
                            }
                            _ => scen,
                        };
                        labels.push((spec.name.clone(), policy, ft, rule));
                        scenarios.push(scen.dag(spec.clone()));
                    }
                }
            }
        }
        let items: Vec<(usize, u64)> = (0..scenarios.len())
            .flat_map(|p| (0..seeds).map(move |s| (p, s)))
            .collect();
        let pool = Pool::new(self.workers);
        // per-worker Scratch: timelines reuse capacity across runs
        let runs: Vec<DagResult> = pool.map_with(items, 1, Scratch::new, |scratch, _, (pi, s)| {
            self.arm_trace(scratch, pi, s);
            scenarios[pi].run_seeded_in(scratch, self.base_seed + s)
        });
        runs.chunks(seeds as usize)
            .zip(labels)
            .map(|(chunk, (dag, policy, ft, rule))| DagSweepRow {
                dag,
                policy,
                ft,
                rule,
                agg: DagAggregate::from_runs(chunk),
                runs: chunk.to_vec(),
            })
            .collect()
    }

    /// Execute the service axis: (services × policies × fts × rules) ×
    /// seeds, fanned out over the pool at per-run steal granularity via
    /// `map_chunked` (a revocation-heavy fleet run costs many times a
    /// clean one).  Rows follow the same fixed enumeration as
    /// [`Sweep::run`] (services outermost, rules innermost), so results
    /// are identical for any `workers` setting.
    pub fn run_services(&self) -> Vec<ServiceSweepRow> {
        if self.services.is_empty() {
            return Vec::new();
        }
        let seeds = self.seeds;
        let shared_curves = self.shared_curves();
        let mut labels = Vec::new();
        let mut scenarios: Vec<ServiceScenario<'_>> = Vec::new();
        for spec in &self.services {
            for &policy in &self.policies {
                for &ft in &self.fts {
                    for &rule in &self.rules {
                        let scen = Scenario::on(self.world)
                            .policy(policy)
                            .ft(ft)
                            .rule(rule)
                            .start_t(self.start_t)
                            .max_sessions(self.max_sessions);
                        let scen = match (&policy, &shared_curves) {
                            (PolicyKind::Predictive(_), Some(curves)) => {
                                scen.with_curves(curves.clone())
                            }
                            _ => scen,
                        };
                        labels.push((spec.name.clone(), policy, ft, rule));
                        scenarios.push(scen.service(spec.clone()));
                    }
                }
            }
        }
        let items: Vec<(usize, u64)> = (0..scenarios.len())
            .flat_map(|p| (0..seeds).map(move |s| (p, s)))
            .collect();
        let pool = Pool::new(self.workers);
        // per-worker Scratch: timelines reuse capacity across runs
        let runs: Vec<ServiceResult> =
            pool.map_with(items, 1, Scratch::new, |scratch, _, (pi, s)| {
                self.arm_trace(scratch, pi, s);
                scenarios[pi].run_seeded_in(scratch, self.base_seed + s)
            });
        runs.chunks(seeds as usize)
            .zip(labels)
            .map(|(chunk, (service, policy, ft, rule))| ServiceSweepRow {
                service,
                policy,
                ft,
                rule,
                agg: ServiceAggregate::from_runs(chunk),
                runs: chunk.to_vec(),
            })
            .collect()
    }
}

/// One executed point of the service axis: the aggregate plus the
/// per-seed runs behind it (seed `i` of the row is `base_seed + i`).
#[derive(Clone, Debug)]
pub struct ServiceSweepRow {
    /// Service scenario name.
    pub service: String,
    /// The provisioning policy under test.
    pub policy: PolicyKind,
    /// The fault-tolerance mechanism paired with it.
    pub ft: FtKind,
    /// The revocation arrival rule.
    pub rule: RevocationRule,
    /// The aggregate over all seeds (the plotted bar).
    pub agg: ServiceAggregate,
    /// The per-seed runs behind the aggregate.
    pub runs: Vec<ServiceResult>,
}

/// One executed point of the DAG axis: the aggregate plus the per-seed
/// runs behind it (seed `i` of the row is `base_seed + i`).
#[derive(Clone, Debug)]
pub struct DagSweepRow {
    /// DAG scenario name.
    pub dag: String,
    /// The provisioning policy under test.
    pub policy: PolicyKind,
    /// The fault-tolerance mechanism paired with it.
    pub ft: FtKind,
    /// The revocation arrival rule.
    pub rule: RevocationRule,
    /// The aggregate over all seeds (the plotted bar).
    pub agg: DagAggregate,
    /// The per-seed runs behind the aggregate.
    pub runs: Vec<DagResult>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PSiwoftConfig;

    fn world() -> (World, f64) {
        let mut w = World::generate(48, 1.0, 19);
        let start = w.split_train(0.6);
        (w, start)
    }

    #[test]
    fn cartesian_order_is_rules_fastest() {
        let (w, start) = world();
        let sweep = Sweep::on(&w)
            .jobs([Job::new(1, 2.0, 16.0), Job::new(2, 3.0, 16.0)])
            .policies([PolicyKind::PSiwoft(PSiwoftConfig::default()), PolicyKind::OnDemand])
            .fts([FtKind::None])
            .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
            .start_t(start);
        let pts = sweep.points();
        assert_eq!(pts.len(), 8);
        assert_eq!(sweep.len(), 8);
        assert_eq!(sweep.total_runs(), 8); // seeds defaults to 1
        assert_eq!(sweep.clone().seeds(3).total_runs(), 24);
        assert_eq!(sweep.clone().seeds(3).len(), 8, "len() counts rows, not runs");
        assert_eq!(pts[0].job.id, 1);
        assert_eq!(pts[0].rule, RevocationRule::Trace);
        assert_eq!(pts[1].rule, RevocationRule::ForcedCount { total: 1 });
        assert_eq!(pts[2].policy, PolicyKind::OnDemand);
        assert_eq!(pts[4].job.id, 2);
    }

    #[test]
    fn empty_job_axis_runs_nothing() {
        let (w, _) = world();
        assert!(Sweep::on(&w).is_empty());
        assert!(Sweep::on(&w).run().is_empty());
    }

    #[test]
    fn rows_carry_seeds_runs_and_aggregate() {
        let (w, start) = world();
        let rows = Sweep::on(&w)
            .job(Job::new(1, 2.0, 16.0))
            .policies([PolicyKind::FtSpot])
            .fts([FtKind::Checkpoint { n: 2 }])
            .rules([RevocationRule::ForcedCount { total: 1 }])
            .seeds(3)
            .start_t(start)
            .workers(1)
            .run();
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.runs.len(), 3);
        assert_eq!(row.agg.n, 3);
        assert_eq!(row.agg, AggregateResult::from_runs(&row.runs));
        assert_eq!(row.agg.mean_revocations, 1.0);
    }

    #[test]
    fn dag_axis_enumerates_and_aggregates() {
        let (w, start) = world();
        let spec = DagSpec::new("two")
            .stage("a", 2.0, 8.0, &[])
            .stage("b", 1.0, 8.0, &["a"]);
        let rows = Sweep::on(&w)
            .dag(spec)
            .policies([PolicyKind::default(), PolicyKind::FtSpot])
            .fts([FtKind::None])
            .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
            .seeds(2)
            .start_t(start)
            .workers(1)
            .run_dags();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].dag, "two");
        assert_eq!(rows[0].rule, RevocationRule::Trace);
        assert_eq!(rows[1].rule, RevocationRule::ForcedCount { total: 1 });
        assert_eq!(rows[2].policy, PolicyKind::FtSpot);
        for row in &rows {
            assert_eq!(row.runs.len(), 2);
            assert_eq!(row.agg.n, 2);
            assert_eq!(row.agg.stages.len(), 2);
            assert!(row.agg.completion_rate > 0.99, "{:?} did not complete", row.rule);
        }
        // the forced-count rows demonstrably revoked
        assert!(rows[1].agg.mean_revocations >= 1.0 - 1e-9);
        // a DAG-less sweep runs nothing
        assert!(Sweep::on(&w).run_dags().is_empty());
    }

    #[test]
    fn service_axis_enumerates_and_aggregates() {
        use crate::service::{ServiceSpec, TierSpec};
        let (w, start) = world();
        let spec = ServiceSpec::new("mini")
            .horizon(12.0)
            .capacity(64.0)
            .tier(TierSpec::open("web", 2, 8.0).slack(0.25));
        let rows = Sweep::on(&w)
            .service(spec)
            .policies([PolicyKind::default(), PolicyKind::OnDemand])
            .fts([FtKind::None])
            .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
            .seeds(2)
            .start_t(start)
            .workers(1)
            .run_services();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].service, "mini");
        assert_eq!(rows[0].rule, RevocationRule::Trace);
        assert_eq!(rows[1].rule, RevocationRule::ForcedCount { total: 1 });
        assert_eq!(rows[2].policy, PolicyKind::OnDemand);
        for row in &rows {
            assert_eq!(row.runs.len(), 2);
            assert_eq!(row.agg.n, 2);
            assert_eq!(row.agg.tiers.len(), 1);
            assert!(row.agg.mean_cost_usd > 0.0);
        }
        // the forced-count spot rows demonstrably revoked; on-demand
        // bins are never revocable
        assert!(rows[1].agg.mean_revocations >= 1.0 - 1e-9);
        assert_eq!(rows[3].agg.mean_revocations, 0.0);
        // a service-less sweep runs nothing
        assert!(Sweep::on(&w).run_services().is_empty());
    }

    #[test]
    fn injected_curves_reproduce_trained_results() {
        let (w, start) = world();
        let build = || {
            Sweep::on(&w)
                .job(Job::new(1, 2.0, 16.0))
                .policies([PolicyKind::parse("predictive").unwrap(), PolicyKind::default()])
                .rules([RevocationRule::Trace, RevocationRule::ForcedCount { total: 1 }])
                .seeds(2)
                .start_t(start)
                .workers(1)
        };
        let fresh = build().run();
        let fit = PolicyKind::train_survival_curves(&w, start);
        let injected = build().curves(fit).run();
        assert_eq!(fresh.len(), injected.len());
        for (a, b) in fresh.iter().zip(&injected) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.agg, b.agg);
            for (x, y) in a.runs.iter().zip(&b.runs) {
                assert_eq!(x.ledger, y.ledger);
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let (w, start) = world();
        let base = |workers| {
            Sweep::on(&w)
                .jobs([Job::new(1, 2.0, 16.0), Job::new(2, 4.0, 16.0)])
                .policies([PolicyKind::default(), PolicyKind::FtSpot])
                .fts([FtKind::None, FtKind::CheckpointHourly])
                .rules([RevocationRule::Trace, RevocationRule::ForcedRate { per_day: 6.0 }])
                .seeds(2)
                .start_t(start)
                .workers(workers)
                .run()
        };
        let serial = base(1);
        let parallel = base(4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.agg, b.agg);
            for (x, y) in a.runs.iter().zip(&b.runs) {
                assert_eq!(x.ledger, y.ledger);
            }
        }
    }
}
