//! Declarative policy / FT-mechanism registries.
//!
//! `PolicyKind` and `FtKind` are the *names* of the pluggable pieces: a
//! kind can be parsed from a CLI/TOML string (`parse`) and instantiated
//! into the trait object the simulator consumes (`build`).  Every layer
//! that used to hand-match strings to constructors — the `siwoft`
//! subcommands, the TOML configs under `rust/configs/`, the experiment
//! drivers, the TCP control plane — goes through these two enums, so a
//! new policy or mechanism is registered in exactly one place.

use crate::ft::{
    Checkpointing, DalyCheckpointing, FtMechanism, Migration, NoFt, Replication,
};
use crate::job::Job;
use crate::market::analytics::SurvivalCurves;
use crate::policy::{
    FtSpotPolicy, GreedyCheapest, OnDemandPolicy, PSiwoft, PSiwoftConfig, Policy,
    PredictiveConfig, PredictivePolicy,
};
use crate::sim::World;

/// Declarative policy selection (so configs/CLI/benches can name them).
#[derive(Clone, Copy, Debug, PartialEq)]
#[allow(clippy::derive_partial_eq_without_eq)]
pub enum PolicyKind {
    /// P-SIWOFT (Algorithm 1) with its config.
    PSiwoft(PSiwoftConfig),
    /// The paper's fault-tolerant spot baseline.
    FtSpot,
    /// Pure on-demand provisioning.
    OnDemand,
    /// Greedy cheapest-market spot selection.
    Greedy,
    /// survival-probability baseline (ref. \[17\]); trains its curves on
    /// the trace prefix `[0, start_t)` of the scenario it runs in
    Predictive(PredictiveConfig),
}

impl Default for PolicyKind {
    /// The paper's protagonist: P-SIWOFT with its default configuration.
    fn default() -> Self {
        PolicyKind::PSiwoft(PSiwoftConfig::default())
    }
}

impl PolicyKind {
    /// Instantiate the policy for a run starting at `start_t` in
    /// `world`.  Most kinds ignore the context; `Predictive` uses it to
    /// train its survival curves on the pre-`start_t` trace prefix
    /// (mirroring `PredictivePolicy::from_world_trained`).
    pub fn build(&self, world: &World, start_t: f64) -> Box<dyn Policy> {
        match *self {
            PolicyKind::PSiwoft(cfg) => Box::new(PSiwoft::new(cfg)),
            PolicyKind::FtSpot => Box::new(FtSpotPolicy::new()),
            PolicyKind::OnDemand => Box::new(OnDemandPolicy),
            PolicyKind::Greedy => Box::new(GreedyCheapest::new()),
            PolicyKind::Predictive(cfg) => {
                let curves = PolicyKind::train_survival_curves(world, start_t);
                Box::new(PredictivePolicy::new(curves, cfg))
            }
        }
    }

    /// The one training recipe behind every `Predictive` instantiation
    /// (`build` and the `Scenario` per-point cache): survival curves
    /// fitted on the trace prefix `[0, start_t)`, clamped into
    /// `[min(2, hours), hours]` so short traces never produce an
    /// invalid window (a zero-hour trace is degenerate everywhere in
    /// the crate and still asserts inside `PriceTrace::window`).
    pub(crate) fn train_survival_curves(world: &World, start_t: f64) -> SurvivalCurves {
        let hours = world.trace.hours.max(1);
        let train_h = (start_t as usize).clamp(2.min(hours), hours);
        if (start_t as usize) < train_h {
            crate::log_warn!(
                "predictive training window floored to [0, {train_h}) but the scenario starts \
                 at t={start_t}: the fit overlaps the evaluated hours (train/eval leakage); \
                 give the scenario a start_t past the training prefix"
            );
        }
        let train = world.trace.window(0, train_h);
        SurvivalCurves::compute(&train, &world.od, SurvivalCurves::DEFAULT_T)
    }

    /// Parse a policy name as written in configs / on the CLI.
    pub fn parse(name: &str) -> Option<PolicyKind> {
        match name {
            "p-siwoft" | "psiwoft" | "p" => Some(PolicyKind::PSiwoft(PSiwoftConfig::default())),
            "ft-spot" | "ft" | "f" => Some(PolicyKind::FtSpot),
            "on-demand" | "ondemand" | "o" => Some(PolicyKind::OnDemand),
            "greedy" | "g" => Some(PolicyKind::Greedy),
            "predictive" | "pred" => Some(PolicyKind::Predictive(PredictiveConfig::default())),
            _ => None,
        }
    }

    /// Canonical CLI/TOML name (the first alias `parse` accepts).
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::PSiwoft(_) => "p-siwoft",
            PolicyKind::FtSpot => "ft-spot",
            PolicyKind::OnDemand => "on-demand",
            PolicyKind::Greedy => "greedy",
            PolicyKind::Predictive(_) => "predictive",
        }
    }

    /// Every registered kind at its default configuration — the grid
    /// axis used by the equivalence and round-trip suites.
    pub fn all() -> Vec<PolicyKind> {
        vec![
            PolicyKind::PSiwoft(PSiwoftConfig::default()),
            PolicyKind::FtSpot,
            PolicyKind::OnDemand,
            PolicyKind::Greedy,
            PolicyKind::Predictive(PredictiveConfig::default()),
        ]
    }
}

/// Declarative FT-mechanism selection.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum FtKind {
    /// P-SIWOFT's pairing: restart from scratch on revocation
    #[default]
    None,
    /// Checkpoint every `1/n` of the job (paper-style periodic FT).
    Checkpoint {
        n: u32,
    },
    /// SpotOn-style hourly checkpoints scaled to the job length
    CheckpointHourly,
    /// Live migration ahead of predicted revocations.
    Migration,
    /// Run `k` replicas in distinct failure groups.
    Replication {
        k: u32,
    },
    /// Young/Daly-optimal checkpoint interval for an expected MTTR
    Daly {
        expected_mttr_h: f64,
    },
}

impl FtKind {
    /// Instantiate the mechanism for `job`.
    pub fn build(&self, job: &Job) -> Box<dyn FtMechanism> {
        match *self {
            FtKind::None => Box::new(NoFt),
            FtKind::Checkpoint { n } => Box::new(Checkpointing::new(n)),
            FtKind::CheckpointHourly => Box::new(Checkpointing::hourly(job.exec_len_h)),
            FtKind::Migration => Box::new(Migration),
            FtKind::Replication { k } => Box::new(Replication::new(k)),
            FtKind::Daly { expected_mttr_h } => Box::new(DalyCheckpointing::new(expected_mttr_h)),
        }
    }

    /// Parse an FT mechanism name as written in configs / on the CLI.
    pub fn parse(name: &str) -> Option<FtKind> {
        match name {
            "none" => Some(FtKind::None),
            "checkpoint" | "ckpt" => Some(FtKind::CheckpointHourly),
            "migration" | "migrate" => Some(FtKind::Migration),
            "replication" | "repl" => Some(FtKind::Replication { k: 2 }),
            "daly" => Some(FtKind::Daly { expected_mttr_h: 8.0 }),
            _ => {
                if let Some(n) = name.strip_prefix("ckpt:") {
                    n.parse().ok().map(|n| FtKind::Checkpoint { n })
                } else if let Some(k) = name.strip_prefix("repl:") {
                    k.parse().ok().map(|k| FtKind::Replication { k })
                } else if let Some(m) = name.strip_prefix("daly:") {
                    m.parse().ok().map(|expected_mttr_h| FtKind::Daly { expected_mttr_h })
                } else {
                    None
                }
            }
        }
    }

    /// Canonical CLI/TOML name.
    pub fn label(&self) -> String {
        match *self {
            FtKind::None => "none".to_string(),
            FtKind::Checkpoint { n } => format!("ckpt:{n}"),
            FtKind::CheckpointHourly => "checkpoint".to_string(),
            FtKind::Migration => "migration".to_string(),
            FtKind::Replication { k } => format!("repl:{k}"),
            FtKind::Daly { expected_mttr_h } => format!("daly:{expected_mttr_h}"),
        }
    }

    /// Every registered kind at a representative setting — the grid
    /// axis used by the equivalence and round-trip suites.
    pub fn all() -> Vec<FtKind> {
        vec![
            FtKind::None,
            FtKind::Checkpoint { n: 4 },
            FtKind::CheckpointHourly,
            FtKind::Migration,
            FtKind::Replication { k: 2 },
            FtKind::Daly { expected_mttr_h: 8.0 },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_parse() {
        assert_eq!(PolicyKind::parse("p"), Some(PolicyKind::PSiwoft(PSiwoftConfig::default())));
        assert_eq!(PolicyKind::parse("ft"), Some(PolicyKind::FtSpot));
        assert_eq!(PolicyKind::parse("ondemand"), Some(PolicyKind::OnDemand));
        assert_eq!(
            PolicyKind::parse("predictive"),
            Some(PolicyKind::Predictive(PredictiveConfig::default()))
        );
        assert_eq!(PolicyKind::parse("nope"), None);
        assert_eq!(FtKind::parse("ckpt:12"), Some(FtKind::Checkpoint { n: 12 }));
        assert_eq!(FtKind::parse("repl:3"), Some(FtKind::Replication { k: 3 }));
        assert_eq!(FtKind::parse("daly:2.5"), Some(FtKind::Daly { expected_mttr_h: 2.5 }));
        assert_eq!(FtKind::parse("none"), Some(FtKind::None));
        assert_eq!(FtKind::parse("zzz"), None);
    }

    #[test]
    fn labels_round_trip_through_parse() {
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(p.label()), Some(p), "policy label {}", p.label());
        }
        for f in FtKind::all() {
            assert_eq!(FtKind::parse(&f.label()), Some(f), "ft label {}", f.label());
        }
    }

    #[test]
    fn defaults_are_the_paper_pairing() {
        assert_eq!(PolicyKind::default(), PolicyKind::PSiwoft(PSiwoftConfig::default()));
        assert_eq!(FtKind::default(), FtKind::None);
    }

    #[test]
    fn build_produces_named_instances() {
        let world = World::generate(24, 0.5, 3);
        let job = Job::new(1, 4.0, 16.0);
        for kind in PolicyKind::all() {
            let p = kind.build(&world, 100.0);
            assert!(!p.name().is_empty());
        }
        for kind in FtKind::all() {
            let f = kind.build(&job);
            assert!(!f.name().is_empty());
        }
        // degree flows through the registry
        assert_eq!(FtKind::Replication { k: 3 }.build(&job).degree(), 3);
        assert_eq!(FtKind::None.build(&job).degree(), 1);
    }
}
