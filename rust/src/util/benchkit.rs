//! Benchmark harness (criterion substitute).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, timed iterations with adaptive batch sizing, robust summary
//! statistics (mean / p50 / p99), and aligned table output.  Results can
//! also be dumped as CSV for EXPERIMENTS.md.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::percentile;
use crate::obs::Histogram;

#[derive(Clone, Debug)]
/// One benchmark's timing summary.
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean time per iteration (ns).
    pub mean_ns: f64,
    /// Median time per iteration (ns).
    pub p50_ns: f64,
    /// 99th-percentile time per iteration (ns).
    pub p99_ns: f64,
    /// Standard deviation of per-iteration times (ns).
    pub std_ns: f64,
    /// optional throughput unit count per iteration (e.g. events)
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    /// Units processed per second, when `units_per_iter` is set.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.mean_ns * 1e-9))
    }
}

/// A warmup-then-measure micro-benchmark harness.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
        }
    }
}

impl Bench {
    /// A fast profile for smoke runs (50 ms warmup, 200 ms measure).
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_samples: 5,
        }
    }

    /// A profile with explicit warmup/measure durations (ms).
    pub fn with_times(warmup_ms: u64, measure_ms: u64) -> Self {
        Bench {
            warmup: Duration::from_millis(warmup_ms),
            measure: Duration::from_millis(measure_ms),
            min_samples: 5,
        }
    }

    /// Measure `f`, returning summary stats. `f`'s return value is
    /// black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // warmup + estimate cost
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / warm_iters as f64).max(1.0);
        // batch so each sample is ≳ 100 µs (amortize timer overhead)
        let batch = ((100_000.0 / est_ns).ceil() as u64).max(1);
        let mut samples = Vec::new();
        let measure_start = Instant::now();
        while measure_start.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let std = (samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n).sqrt();
        BenchResult {
            name: name.to_string(),
            iters: batch * samples.len() as u64,
            mean_ns: mean,
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            std_ns: std,
            units_per_iter: None,
        }
    }

    /// Like `run`, but tags each iteration as processing `units` items so
    /// the report can show throughput (items/s).
    pub fn run_with_units<T>(
        &self,
        name: &str,
        units: f64,
        f: impl FnMut() -> T,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.units_per_iter = Some(units);
        r
    }
}

/// A scoped profiling timer: measures the wall time from construction
/// to drop and records it (µs) into a lock-free [`Histogram`]
/// (`obs::hist`).  This is the hook `bench --area engine|service` uses
/// to attribute time to phases inside a benchmarked iteration — the
/// histogram's snapshot renders straight into a bench row.
pub struct ScopeTimer<'a> {
    hist: &'a Histogram,
    t0: Instant,
}

impl<'a> ScopeTimer<'a> {
    /// Start timing a scope; the elapsed µs land in `hist` at drop.
    pub fn start(hist: &'a Histogram) -> ScopeTimer<'a> {
        ScopeTimer { hist, t0: Instant::now() }
    }
}

impl Drop for ScopeTimer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.t0.elapsed().as_micros() as u64);
    }
}

/// Format a duration in adaptive units (`ns`/`µs`/`ms`/`s`).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a rate in adaptive units (`/s`, `k/s`, `M/s`).
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

/// Collects results and prints an aligned report.
#[derive(Default)]
pub struct Suite {
    /// Table title (printed as the header line).
    pub title: String,
    /// The collected rows.
    pub results: Vec<BenchResult>,
}

impl Suite {
    /// An empty table titled `title`.
    pub fn new(title: &str) -> Self {
        Suite { title: title.to_string(), results: Vec::new() }
    }

    /// Append one result row (also prints it immediately).
    pub fn push(&mut self, r: BenchResult) {
        println!(
            "  {:<44} {:>12} {:>12} {:>12}{}",
            r.name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
            r.throughput().map(|t| format!("  {:>12}", fmt_rate(t))).unwrap_or_default()
        );
        self.results.push(r);
    }

    /// Print the column header line.
    pub fn header(&self) {
        println!("\n== {} ==", self.title);
        println!(
            "  {:<44} {:>12} {:>12} {:>12} {:>13}",
            "benchmark", "mean", "p50", "p99", "throughput"
        );
    }

    /// Render the table as CSV rows (header + one row per result).
    pub fn to_csv(&self) -> Vec<Vec<String>> {
        let mut rows = vec![crate::csv_row!["name", "mean_ns", "p50_ns", "p99_ns", "std_ns", "iters", "throughput_per_s"]];
        for r in &self.results {
            rows.push(crate::csv_row![
                r.name,
                r.mean_ns,
                r.p50_ns,
                r.p99_ns,
                r.std_ns,
                r.iters,
                r.throughput().unwrap_or(f64::NAN)
            ]);
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.p50_ns <= r.p99_ns * 1.001);
    }

    #[test]
    fn throughput_computed() {
        let b = Bench::quick();
        let r = b.run_with_units("units", 1000.0, || 42);
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn scope_timer_records_on_drop() {
        let h = Histogram::new();
        {
            let _t = ScopeTimer::start(&h);
            black_box(42);
        }
        {
            let _t = ScopeTimer::start(&h);
            black_box(43);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 2);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert!(fmt_ns(1.5e4).contains("µs"));
        assert!(fmt_ns(2.5e7).contains("ms"));
        assert!(fmt_ns(3.0e9).contains(" s"));
        assert!(fmt_rate(5e6).contains("M/s"));
    }
}
