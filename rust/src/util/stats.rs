//! Streaming and batch statistics used by the accounting layer, the
//! benchmark harness and the experiment tables.

/// Welford online accumulator: numerically stable mean/variance.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// A fresh accumulator with no samples.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample into the running moments.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Fold another accumulator's samples into this one.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }
    /// Sample variance (n-1).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    /// Sample standard deviation (0 with fewer than two samples).
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Half-width of the 95% normal-approximation confidence interval.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 { 0.0 } else { 1.96 * self.std() / (self.n as f64).sqrt() }
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 100.0);
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Sort a copy and return (p50, p90, p99).
pub fn percentiles(xs: &[f64]) -> (f64, f64, f64) {
    let mut v = xs.to_vec();
    sort_samples(&mut v);
    (percentile(&v, 50.0), percentile(&v, 90.0), percentile(&v, 99.0))
}

/// Sort samples ascending in place — the preparation [`percentile`]
/// expects.  One home for the `partial_cmp` sort every latency
/// collector used to hand-roll (NaN-free inputs assumed, as
/// everywhere in the crate).
pub fn sort_samples(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

/// (p50, p99) of a sample in any order — the latency-report pair
/// `coordinator::loadgen` and the serve/DAG benches print.
pub fn p50_p99(xs: &[f64]) -> (f64, f64) {
    let mut v = xs.to_vec();
    sort_samples(&mut v);
    (percentile(&v, 50.0), percentile(&v, 99.0))
}

/// Percentile `q` (0–100) from log2-bucket counts in the
/// [`crate::obs::hist`] layout — bucket 0 holds exact zeros, bucket
/// `b > 0` covers `[2^(b-1), 2^b)`.  Walks the cumulative mass to the
/// target rank and interpolates linearly within the covering bucket;
/// `count` is the total number of samples.  Returns 0 when empty.
pub fn bucket_percentile(buckets: &[u64], count: u64, q: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let target = q.clamp(0.0, 100.0) / 100.0 * count as f64;
    let mut cum = 0u64;
    for (b, &n) in buckets.iter().enumerate() {
        if n == 0 {
            continue;
        }
        let next = cum + n;
        if next as f64 >= target {
            if b == 0 {
                return 0.0;
            }
            let lo = 2f64.powi(b as i32 - 1);
            let hi = 2f64.powi(b as i32);
            let frac = ((target - cum as f64) / n as f64).clamp(0.0, 1.0);
            return lo + frac * (hi - lo);
        }
        cum = next;
    }
    0.0
}

/// Fixed-width histogram.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    /// A zeroed histogram over `[lo, hi)` with `nbins` equal bins.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    /// Count one sample (out-of-range goes to underflow/overflow).
    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    /// The per-bin counts.
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
    /// Total samples counted, including out-of-range ones.
    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
    /// Samples below `lo`.
    pub fn underflow(&self) -> u64 {
        self.underflow
    }
    /// Samples at or above `hi`.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Render a compact ASCII sparkline of the bin mass.
    pub fn sparkline(&self) -> String {
        const GLYPHS: &[char] = &[' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| GLYPHS[(b as f64 / max as f64 * (GLYPHS.len() - 1) as f64).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basic() {
        let mut w = Welford::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            w.add(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.var() - 2.5).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 5.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        xs.iter().for_each(|&x| all.add(x));
        let mut a = Welford::new();
        let mut b = Welford::new();
        xs[..37].iter().for_each(|&x| a.add(x));
        xs[37..].iter().for_each(|&x| b.add(x));
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
    }

    #[test]
    fn welford_empty() {
        let w = Welford::new();
        assert!(w.mean().is_nan());
        assert_eq!(w.var(), 0.0);
        assert_eq!(w.ci95(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn p50_p99_matches_sorted_percentile() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        let (p50, p99) = p50_p99(&xs);
        let mut sorted = xs.to_vec();
        sort_samples(&mut sorted);
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(p50, percentile(&sorted, 50.0));
        assert_eq!(p99, percentile(&sorted, 99.0));
        assert_eq!(p50, 5.0);
    }

    #[test]
    fn bucket_percentile_interpolates_within_bucket() {
        // 100 samples in bucket 10 ([512, 1024))
        let mut buckets = vec![0u64; 64];
        buckets[10] = 100;
        let p50 = bucket_percentile(&buckets, 100, 50.0);
        assert!((p50 - 768.0).abs() < 1e-9, "p50 {p50}");
        assert_eq!(bucket_percentile(&buckets, 100, 100.0), 1024.0);
        // zero bucket dominates low quantiles
        buckets[0] = 100;
        assert_eq!(bucket_percentile(&buckets, 200, 25.0), 0.0);
        assert_eq!(bucket_percentile(&[], 0, 50.0), 0.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.total(), 12);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert!(h.bins().iter().all(|&b| b == 1));
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
