//! Leveled stderr logger (log-crate substitute).
//!
//! Level comes from `SIWOFT_LOG` (`error|warn|info|debug|trace`,
//! default `info`) or [`set_level`].  Macros `log_info!` etc. are
//! exported at the crate root.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
/// Log verbosity levels, most to least severe.
pub enum Level {
    /// Unrecoverable or dropped-work conditions.
    Error = 0,
    /// Degraded but continuing.
    Warn = 1,
    /// Lifecycle and progress messages (the default).
    Info = 2,
    /// Per-operation detail.
    Debug = 3,
    /// Hot-path detail (disabled in normal runs).
    Trace = 4,
}

impl Level {
    /// The canonical tag used in log lines (no padding: consumers that
    /// tokenize the `[time LEVEL target]` prefix — the periodic metrics
    /// flush checks in CI among them — get a stable token; column
    /// alignment is the formatter's job, see [`log`]).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    /// Parse a level name (case-insensitive), e.g. from `SIWOFT_LOG`.
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("SIWOFT_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    // ordering: LEVEL is a standalone config byte; racing initializers write the same value
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Set the process-wide log level.
pub fn set_level(level: Level) {
    // ordering: LEVEL is a standalone config byte (see init_from_env)
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process-wide log level (lazily read from `SIWOFT_LOG`).
pub fn level() -> Level {
    // ordering: LEVEL read — a stale level only mis-filters a log line
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True when messages at level `l` are currently emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Log a preformatted message (used by the macros).
pub fn log(l: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = start_instant().elapsed();
    // pad the level tag here (not in `as_str`) so the prefix tokenizes
    // to the bare level name while columns still line up
    eprintln!("[{:>9.3}s {:<5} {}] {}", t.as_secs_f64(), l.as_str(), module, args);
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_tags_are_bare_tokens() {
        // the log-line prefix is machine-consumed (CI greps the
        // periodic metrics flush by level tag): no padding allowed in
        // the tag itself, and every tag round-trips through the parser
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug, Level::Trace] {
            let tag = l.as_str();
            assert_eq!(tag, tag.trim(), "padded level tag {tag:?}");
            assert_eq!(Level::from_str(tag), Some(l));
        }
    }
}
