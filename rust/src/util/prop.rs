//! Mini property-based testing harness (proptest substitute).
//!
//! `check(cases, seed, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; a failure reports the failing case (Debug)
//! and the exact sub-seed so it can be replayed with `replay`.  A naive
//! halving shrinker is provided for `Vec` inputs via [`check_shrink`].

use super::rng::Rng;

/// Run `prop` over `cases` generated inputs; panics with a replayable
/// report on the first failure.
pub fn check<T, G, P>(cases: usize, seed: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let sub = root.next_u64();
        let mut rng = Rng::new(sub);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case}/{cases} (seed {seed}, sub-seed {sub}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Replay a single failing case by sub-seed.
pub fn replay<T, G, P>(sub_seed: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(sub_seed);
    let input = gen(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("replayed failure (sub-seed {sub_seed}):\n  input: {input:?}\n  reason: {msg}");
    }
}

/// Vector property with halving shrink: on failure, repeatedly tries
/// dropping the first/second half of the vector while the property
/// still fails, then reports the minimal found counterexample.
pub fn check_shrink<T, G, P>(cases: usize, seed: u64, mut gen: G, mut prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> Vec<T>,
    P: FnMut(&[T]) -> Result<(), String>,
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let sub = root.next_u64();
        let mut rng = Rng::new(sub);
        let input = gen(&mut rng);
        if prop(&input).is_ok() {
            continue;
        }
        // shrink
        let mut best = input;
        loop {
            let n = best.len();
            if n <= 1 {
                break;
            }
            let halves = [best[..n / 2].to_vec(), best[n / 2..].to_vec()];
            match halves.into_iter().find(|h| prop(h).is_err()) {
                Some(smaller) => best = smaller,
                None => break,
            }
        }
        let msg = prop(&best).unwrap_err();
        panic!(
            "property failed on case {case}/{cases} (seed {seed}, sub-seed {sub}):\n  \
             shrunk input ({} elems): {best:?}\n  reason: {msg}",
            best.len()
        );
    }
}

/// Common generators.
pub mod gens {
    use super::Rng;

    /// Generator: a uniform `f64` in `[lo, hi)`.
    pub fn f64_in(lo: f64, hi: f64) -> impl FnMut(&mut Rng) -> f64 {
        move |r| r.range(lo, hi)
    }

    /// Generator: a vector with length in `len` of uniform `f64`s.
    pub fn vec_f64(len: std::ops::Range<usize>, lo: f64, hi: f64) -> impl FnMut(&mut Rng) -> Vec<f64> {
        move |r| {
            let n = len.start + r.below((len.end - len.start).max(1));
            (0..n).map(|_| r.range(lo, hi)).collect()
        }
    }

    /// Generator: a uniform `usize` in `[lo, hi)`.
    pub fn usize_in(lo: usize, hi: usize) -> impl FnMut(&mut Rng) -> usize {
        move |r| lo + r.below((hi - lo).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(200, 1, |r| r.range(0.0, 10.0), |x| {
            if *x >= 0.0 && *x < 10.0 {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(100, 2, |r| r.below(10), |x| {
            if *x < 9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn shrinker_minimizes() {
        // property: no element exceeds 0.95 — shrinker should cut the
        // vector down around the offending element.
        check_shrink(
            50,
            3,
            gens::vec_f64(1..64, 0.0, 1.0),
            |xs| {
                if xs.iter().all(|&x| x < 0.95) {
                    Ok(())
                } else {
                    Err("element >= 0.95".into())
                }
            },
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen1 = Vec::new();
        check(10, 7, |r| r.next_u64(), |x| {
            seen1.push(*x);
            Ok(())
        });
        let mut seen2 = Vec::new();
        check(10, 7, |r| r.next_u64(), |x| {
            seen2.push(*x);
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }
}
