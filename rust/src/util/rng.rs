//! Deterministic pseudo-random generation for the simulator.
//!
//! crates.io is not reachable in the build environment, so this module
//! provides the `rand`-equivalent substrate the whole system uses:
//! a PCG-XSH-RR-64/32-based generator seeded through SplitMix64, plus
//! the distributions the market/trace/workload generators need.
//!
//! Determinism contract: every simulation component owns an `Rng` forked
//! from a root seed via [`Rng::fork`], so results are reproducible per
//! seed regardless of thread scheduling.

/// SplitMix64 — used for seeding / stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 with 64-bit output assembled from two draws.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Rng {
    /// Create a generator from a root seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Create a generator on an explicit stream; distinct streams from
    /// the same seed are statistically independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ 0xD6E8_FEB8_6659_FD93;
        let s0 = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xA076_1D64_78BD_642F;
        let inc = splitmix64(&mut sm2) | 1; // must be odd
        let mut rng = Rng { state: 0, inc, spare_normal: None };
        rng.state = s0.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (e.g. per market, per job).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::with_stream(seed, tag)
    }

    #[inline]
    /// Next 32 random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our needs).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with underlying (mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson (Knuth; fine for the small lambdas the simulator uses).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // pathological lambda guard
            }
        }
    }

    /// Zipf-like rank sampler over [0, n) with exponent s (workload skew).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // inverse-CDF on the (cheap, approximate) normalized weights
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick an element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.below(xs.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn forks_are_independent_and_deterministic() {
        let mut root1 = Rng::new(7);
        let mut root2 = Rng::new(7);
        let mut c1 = root1.fork(3);
        let mut c2 = root2.fork(3);
        for _ in 0..32 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        let mut d = root1.fork(4);
        assert_ne!(c1.next_u64(), d.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let mean = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Rng::new(23);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        // all classes hit
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_to_low_ranks() {
        let mut r = Rng::new(31);
        let mut counts = [0u32; 10];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[5] && counts[0] > counts[9]);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(37);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.5) > 0.0);
        }
    }
}
