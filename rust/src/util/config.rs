//! TOML-subset configuration parser (the config-system substrate).
//!
//! Supports the subset the project's config files use:
//!   * `[section]` and `[section.sub]` headers
//!   * `key = value` with string / integer / float / bool values
//!   * flat arrays of scalars: `lengths = [2, 4, 8]`
//!   * `#` comments, blank lines
//!
//! Values land in a flat `BTreeMap<String, Value>` keyed by the dotted
//! path (`"sweep.lengths"`), with typed getters and helpful errors.

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
/// A typed configuration value as parsed from one `key = value` line.
pub enum Value {
    /// A (possibly quoted) string.
    Str(String),
    /// A decimal integer.
    Int(i64),
    /// A floating-point number.
    Float(f64),
    /// `true` or `false`.
    Bool(bool),
    /// A `[v, v, ...]` array of values.
    Arr(Vec<Value>),
}

impl Value {
    /// The string payload, if this value is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer payload, if this value is a [`Value::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// The numeric payload (ints widen), if this value is numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean payload, if this value is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The array payload, if this value is a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
/// Everything that can go wrong loading or reading a config.
pub enum ConfigError {
    /// A line that does not parse as `key = value`.
    Parse { line: usize, msg: String },
    /// A required key that is absent.
    Missing(String),
    /// A key present with the wrong type.
    Type { key: String, expected: &'static str },
    /// The file could not be read.
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse { line, msg } => {
                write!(f, "config parse error on line {line}: {msg}")
            }
            ConfigError::Missing(key) => write!(f, "missing config key '{key}'"),
            ConfigError::Type { key, expected } => {
                write!(f, "config key '{key}' has wrong type (expected {expected})")
            }
            ConfigError::Io(e) => write!(f, "io error reading config: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> ConfigError {
        ConfigError::Io(e)
    }
}

#[derive(Clone, Debug, Default)]
/// A parsed key/value configuration file (the `--config` format).
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse config text; later duplicate keys override earlier ones.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError::Parse { line: lineno + 1, msg: msg.to_string() };
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(err("unterminated section header"));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(err("empty section name"));
                }
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|m| err(&m))?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, val);
        }
        Ok(Config { values })
    }

    /// Read and parse a config file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Config, ConfigError> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    /// The raw value stored under `key`, if any.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }
    /// Every key in the config, in insertion order.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
    /// Number of keys.
    pub fn len(&self) -> usize {
        self.values.len()
    }
    /// True when the config holds no keys.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Required string under `key`, or a [`ConfigError`].
    pub fn str(&self, key: &str) -> Result<&str, ConfigError> {
        self.req(key)?.as_str().ok_or(ConfigError::Type { key: key.into(), expected: "string" })
    }
    /// Required integer under `key`, or a [`ConfigError`].
    pub fn i64(&self, key: &str) -> Result<i64, ConfigError> {
        self.req(key)?.as_i64().ok_or(ConfigError::Type { key: key.into(), expected: "integer" })
    }
    /// Required float under `key` (ints widen), or a [`ConfigError`].
    pub fn f64(&self, key: &str) -> Result<f64, ConfigError> {
        self.req(key)?.as_f64().ok_or(ConfigError::Type { key: key.into(), expected: "float" })
    }
    /// Required boolean under `key`, or a [`ConfigError`].
    pub fn bool(&self, key: &str) -> Result<bool, ConfigError> {
        self.req(key)?.as_bool().ok_or(ConfigError::Type { key: key.into(), expected: "bool" })
    }
    /// Required array of floats under `key`, or a [`ConfigError`].
    pub fn f64_arr(&self, key: &str) -> Result<Vec<f64>, ConfigError> {
        let arr = self
            .req(key)?
            .as_arr()
            .ok_or(ConfigError::Type { key: key.into(), expected: "array" })?;
        arr.iter()
            .map(|v| v.as_f64().ok_or(ConfigError::Type { key: key.into(), expected: "float array" }))
            .collect()
    }

    // with-default variants
    /// String under `key`, or `default` when absent.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }
    /// Integer under `key`, or `default` when absent.
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }
    /// Float under `key`, or `default` when absent.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }
    /// Boolean under `key`, or `default` when absent.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    fn req(&self, key: &str) -> Result<&Value, ConfigError> {
        self.get(key).ok_or_else(|| ConfigError::Missing(key.to_string()))
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err("unterminated string".into());
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err("unterminated array".into());
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        return inner
            .split(',')
            .map(|part| parse_value(part.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(Value::Arr);
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig1a"          # panel id
seeds = 5

[market]
count = 256
months = 3.0
volatile = true
families = ["m5", "c5"]

[sweep]
lengths = [2, 4, 8, 16, 32]
mem_gb = 16.0
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name").unwrap(), "fig1a");
        assert_eq!(c.i64("seeds").unwrap(), 5);
        assert_eq!(c.i64("market.count").unwrap(), 256);
        assert_eq!(c.f64("market.months").unwrap(), 3.0);
        assert!(c.bool("market.volatile").unwrap());
        assert_eq!(c.f64("sweep.mem_gb").unwrap(), 16.0);
        assert_eq!(c.f64_arr("sweep.lengths").unwrap(), vec![2.0, 4.0, 8.0, 16.0, 32.0]);
    }

    #[test]
    fn string_array() {
        let c = Config::parse(SAMPLE).unwrap();
        let fams = c.get("market.families").unwrap().as_arr().unwrap();
        assert_eq!(fams[0].as_str(), Some("m5"));
        assert_eq!(fams[1].as_str(), Some("c5"));
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.f64("x").unwrap(), 3.0);
    }

    #[test]
    fn defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.i64_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "d"), "d");
        assert!(c.is_empty());
    }

    #[test]
    fn missing_and_type_errors() {
        let c = Config::parse("x = 1").unwrap();
        assert!(matches!(c.str("y"), Err(ConfigError::Missing(_))));
        assert!(matches!(c.str("x"), Err(ConfigError::Type { .. })));
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse(r##"k = "a#b" # trailing"##).unwrap();
        assert_eq!(c.str("k").unwrap(), "a#b");
    }

    #[test]
    fn parse_errors_have_line_numbers() {
        let err = Config::parse("a = 1\nbad line\n").unwrap_err();
        match err {
            ConfigError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("xs = []").unwrap();
        assert_eq!(c.get("xs").unwrap().as_arr().unwrap().len(), 0);
    }
}
