//! Shared substrates: everything a crates.io-equipped project would pull
//! from `rand`, `serde_json`, `toml`, `clap`, `log`, `proptest` and
//! `criterion`, built in-tree because the build environment is offline.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod csvio;
pub mod error;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;
