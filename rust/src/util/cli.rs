//! Command-line argument parsing (the clap substitute).
//!
//! Model: a binary has subcommands; each subcommand declares typed
//! options (`--name <value>`), boolean flags, and generates its own
//! `--help`.  Kept intentionally small: exactly what `siwoft`'s CLI and
//! the examples need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
/// One option accepted by a subcommand.
pub struct OptSpec {
    /// Option name as written on the CLI (without `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Default value (`None` = the option is required).
    pub default: Option<&'static str>,
    /// True for boolean flags that take no value.
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
/// A subcommand's full CLI interface: options, defaults, usage text.
pub struct CommandSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description shown in help.
    pub about: &'static str,
    /// The options this subcommand accepts.
    pub opts: Vec<OptSpec>,
}

impl CommandSpec {
    /// Start a spec for subcommand `name` (builder style).
    pub fn new(name: &'static str, about: &'static str) -> Self {
        CommandSpec { name, about, opts: Vec::new() }
    }

    /// Add an option with a default value.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    /// Add a required option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Register the global `--workers` option shared by every
    /// subcommand that fans work out over `coordinator::Pool`.  The
    /// default `0` resolves through the `SIWOFT_WORKERS` environment
    /// variable, then to one worker per available CPU
    /// (`std::thread::available_parallelism`) inside `Pool::new`.
    pub fn workers_opt(self) -> Self {
        self.opt(
            "workers",
            "0",
            "worker threads for parallel fan-out (0 = $SIWOFT_WORKERS, else one per CPU core)",
        )
    }

    /// Render the usage/help text for this subcommand.
    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n\noptions:", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "".to_string() } else { " <value>".to_string() };
            let def = match o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{}{kind}\n        {}{def}", o.name, o.help);
        }
        s
    }

    /// Parse raw args (everything after the subcommand name).
    pub fn parse(&self, raw: &[String]) -> Result<Args, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.to_string(), false);
            } else if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            let name = a
                .strip_prefix("--")
                .ok_or_else(|| format!("unexpected argument '{a}'\n\n{}", self.usage()))?;
            // support --name=value
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let spec = self
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| format!("unknown option '--{name}'\n\n{}", self.usage()))?;
            if spec.is_flag {
                if inline.is_some() {
                    return Err(format!("flag '--{name}' takes no value"));
                }
                flags.insert(name.to_string(), true);
            } else {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        raw.get(i)
                            .cloned()
                            .ok_or_else(|| format!("option '--{name}' needs a value"))?
                    }
                };
                values.insert(name.to_string(), v);
            }
            i += 1;
        }
        // required check
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !values.contains_key(o.name) {
                return Err(format!("missing required option '--{}'\n\n{}", o.name, self.usage()));
            }
        }
        Ok(Args { values, flags })
    }
}

#[derive(Clone, Debug, Default)]
/// Parsed arguments: every option resolved to its value.
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    /// The value of option `name` (defaults applied).
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(String::as_str).unwrap_or("")
    }
    /// Whether flag `name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
    /// Option `name` parsed as `u64`.
    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.str(name).parse().map_err(|_| format!("--{name} must be an integer"))
    }
    /// Option `name` parsed as `usize`.
    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.str(name).parse().map_err(|_| format!("--{name} must be an integer"))
    }
    /// The `--workers` value registered via [`CommandSpec::workers_opt`].
    /// The auto default (`0`) resolves inside `Pool::new`: first the
    /// `SIWOFT_WORKERS` environment variable (how the CI test matrix
    /// pins every auto-sized pool, CLI or library), then one worker per
    /// available CPU.
    pub fn workers(&self) -> Result<usize, String> {
        self.usize("workers")
    }
    /// Option `name` parsed as `f64`.
    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.str(name).parse().map_err(|_| format!("--{name} must be a number"))
    }
    /// Comma-separated f64 list.
    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>, String> {
        self.str(name)
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().parse().map_err(|_| format!("--{name}: bad number '{s}'")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CommandSpec {
        CommandSpec::new("test", "a test command")
            .opt("seed", "42", "rng seed")
            .opt("out", "results", "output dir")
            .req("traces", "trace dir")
            .flag("verbose", "chatty")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&s(&["--traces", "t"])).unwrap();
        assert_eq!(a.str("seed"), "42");
        assert_eq!(a.u64("seed").unwrap(), 42);
        assert_eq!(a.str("traces"), "t");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = spec()
            .parse(&s(&["--seed", "7", "--traces", "x", "--verbose"]))
            .unwrap();
        assert_eq!(a.u64("seed").unwrap(), 7);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = spec().parse(&s(&["--traces=foo", "--seed=9"])).unwrap();
        assert_eq!(a.str("traces"), "foo");
        assert_eq!(a.u64("seed").unwrap(), 9);
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&s(&["--seed", "7"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(&s(&["--traces", "t", "--nope", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(spec().parse(&s(&["--traces", "t", "--verbose=1"])).is_err());
    }

    #[test]
    fn help_is_err_with_usage() {
        let err = spec().parse(&s(&["--help"])).unwrap_err();
        assert!(err.contains("--seed"));
        assert!(err.contains("a test command"));
    }

    #[test]
    fn f64_list() {
        let sp = CommandSpec::new("x", "").opt("xs", "1,2.5,3", "numbers");
        let a = sp.parse(&[]).unwrap();
        assert_eq!(a.f64_list("xs").unwrap(), vec![1.0, 2.5, 3.0]);
    }

    #[test]
    fn workers_opt_defaults_to_auto() {
        // no env set/remove here: SIWOFT_WORKERS is read (not mutated)
        // by Pool::new on the 0 path, and mutating process env from a
        // parallel test thread races glibc getenv
        let sp = CommandSpec::new("x", "").workers_opt();
        let a = sp.parse(&[]).unwrap();
        assert_eq!(a.workers().unwrap(), 0);
        let a = sp.parse(&s(&["--workers", "3"])).unwrap();
        assert_eq!(a.workers().unwrap(), 3);
        assert!(sp.usage().contains("--workers"));
    }
}
