//! Minimal JSON: value model, recursive-descent parser, printer.
//!
//! Used to read `artifacts/manifest.json`, to serialize experiment
//! results, and as the wire format of the coordinator's TCP control
//! plane.  Serde is unavailable offline, so this is the substrate.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
/// A JSON value (objects keep keys sorted via `BTreeMap`, so rendering is deterministic).
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (all JSON numbers are `f64` here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` keeps key order stable.
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
/// A parse error with its byte offset in the input.
pub struct JsonError {
    /// Byte offset where parsing failed.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ------------------------------------------------
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Build an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    /// Build a number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    // ---- accessors ---------------------------------------------------
    /// Member `key` of an object, if present.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Element `i` of an array, if present.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// The numeric payload truncated to `i64`, if integral.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    /// The numeric payload as `usize`, if integral and non-negative.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["artifacts", "0", "file"])`.
    pub fn path(&self, segments: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for s in segments {
            cur = match cur {
                Json::Obj(_) => cur.get(s)?,
                Json::Arr(_) => cur.idx(s.parse().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // ---- parsing -----------------------------------------------------
    /// Parse JSON text (the full document; trailing garbage is an error).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a", "2", "b"]).unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
        assert_eq!(j.path(&["a", "0"]).unwrap().as_i64(), Some(1));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\"A"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"flag":true,"nested":{"k":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn display_escapes() {
        let j = Json::str("a\"b\nc");
        assert_eq!(j.to_string(), r#""a\"b\nc""#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
