//! Minimal error-context substrate (the anyhow substitute).
//!
//! Offline build: no crates.io, so the few modules that want
//! anyhow-style ergonomics (`runtime`, `coordinator::server`) use this
//! instead.  [`Error`] is a flattened message chain; [`Context`] adds a
//! prefix the way `anyhow::Context` does, and works on both `Result`
//! and `Option`.  The [`err!`](crate::err) / [`bail!`](crate::bail)
//! macros mirror `anyhow!` / `bail!`.

use std::fmt;

/// A flattened error: the full context chain rendered into one string.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// An error from a plain message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prefix the chain with one more layer of context.
    pub fn wrap(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (anyhow's whole-chain form) and `{}` both print the
        // flattened chain.
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

/// Crate-standard result type (error defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_on_result_prefixes() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("open artifact").unwrap_err();
        assert_eq!(e.to_string(), "open artifact: gone");
        assert_eq!(format!("{e:#}"), "open artifact: gone");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        let e = crate::err!("bad shape {}x{}", 2, 3);
        assert_eq!(e.to_string(), "bad shape 2x3");
        fn f() -> Result<()> {
            crate::bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn wrap_chains() {
        let e = Error::msg("root cause").wrap("layer");
        assert_eq!(e.to_string(), "layer: root cause");
    }
}
