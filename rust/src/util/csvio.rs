//! Small CSV reader/writer for price traces and result tables.
//!
//! Handles quoting of fields containing commas/quotes/newlines; that is
//! all the project's interchange needs (no streaming, no Serde).

use std::io::{self, Write};
use std::path::Path;

/// Serialize rows to CSV text.
pub fn to_string(rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    for row in rows {
        for (i, field) in row.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&escape(field));
        }
        out.push('\n');
    }
    out
}

/// Write rows to a file, creating parent directories.
pub fn write_file(path: impl AsRef<Path>, rows: &[Vec<String>]) -> io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_string(rows).as_bytes())
}

/// Parse CSV text into rows of fields.
pub fn parse(text: &str) -> Result<Vec<Vec<String>>, String> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        return Err("quote in unquoted field".into());
                    }
                    in_quotes = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quoted field".into());
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

/// Read and parse a CSV file.
pub fn read_file(path: impl AsRef<Path>) -> Result<Vec<Vec<String>>, String> {
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
    parse(&text)
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Convenience: render a row of display-ables.
#[macro_export]
macro_rules! csv_row {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let rows = vec![
            vec!["a".to_string(), "b".to_string()],
            vec!["1".to_string(), "2.5".to_string()],
        ];
        let parsed = parse(&to_string(&rows)).unwrap();
        assert_eq!(parsed, rows);
    }

    #[test]
    fn roundtrip_quoted() {
        let rows = vec![vec!["x,y".to_string(), "he said \"hi\"".to_string(), "a\nb".to_string()]];
        let parsed = parse(&to_string(&rows)).unwrap();
        assert_eq!(parsed, rows);
    }

    #[test]
    fn empty_fields() {
        let parsed = parse("a,,c\n,,\n").unwrap();
        assert_eq!(parsed[0], vec!["a", "", "c"]);
        assert_eq!(parsed[1], vec!["", "", ""]);
    }

    #[test]
    fn crlf() {
        let parsed = parse("a,b\r\nc,d\r\n").unwrap();
        assert_eq!(parsed, vec![vec!["a", "b"], vec!["c", "d"]]);
    }

    #[test]
    fn no_trailing_newline() {
        let parsed = parse("a,b\nc,d").unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[1], vec!["c", "d"]);
    }

    #[test]
    fn rejects_bad_quotes() {
        assert!(parse("a\"b,c\n").is_err());
        assert!(parse("\"open\n").is_err());
    }

    #[test]
    fn csv_row_macro() {
        let row = csv_row!["a", 1, 2.5];
        assert_eq!(row, vec!["a", "1", "2.5"]);
    }
}
