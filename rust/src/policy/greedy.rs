//! Greedy-cheapest ablation policy: always chase the currently cheapest
//! suitable spot price with *no* lifetime awareness and *no* correlation
//! filtering, and no FT mechanism.  Isolates how much of P-SIWOFT's win
//! comes from its market analytics rather than from merely "using spot
//! without FT".

use super::{Ctx, Decision, Policy};
use crate::job::Job;

#[derive(Clone, Debug, Default)]
/// Greedy baseline: always the cheapest spot market right now.
pub struct GreedyCheapest {
    last_revoked: Option<usize>,
}

impl GreedyCheapest {
    /// A fresh greedy policy.
    pub fn new() -> Self {
        GreedyCheapest::default()
    }
}

impl Policy for GreedyCheapest {
    fn name(&self) -> &'static str {
        "greedy-cheapest"
    }

    fn select(&mut self, job: &Job, ctx: &Ctx<'_>) -> Decision {
        let w = ctx.world;
        let mut best: Option<(usize, f32)> = None;
        for id in w.catalog.suitable(job.mem_gb) {
            if Some(id) == self.last_revoked {
                continue; // only skip the market that just died
            }
            let p = w.market(id).price_at(ctx.now);
            match best {
                Some((_, bp)) if bp <= p => {}
                _ => best = Some((id, p)),
            }
        }
        match best {
            Some((id, _)) => Decision::Spot { market: id },
            None => Decision::Spot {
                market: w.catalog.suitable(job.mem_gb)[0],
            },
        }
    }

    fn on_revocation(&mut self, _job: &Job, market: usize, _ctx: &Ctx<'_>) {
        self.last_revoked = Some(market);
    }

    fn reset(&mut self) {
        self.last_revoked = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::world::World;

    #[test]
    fn chases_spot_price() {
        let w = World::generate(48, 0.25, 9);
        let ctx = Ctx { world: &w, now: 12.0 };
        let job = Job::new(1, 4.0, 8.0);
        let mut p = GreedyCheapest::new();
        let d = p.select(&job, &ctx);
        assert!(d.is_spot());
        let chosen = d.market();
        for id in w.catalog.suitable(8.0) {
            assert!(w.market(chosen).price_at(12.0) <= w.market(id).price_at(12.0) + 1e-6);
        }
    }

    #[test]
    fn avoids_only_last_revoked() {
        let w = World::generate(24, 0.25, 10);
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 4.0, 8.0);
        let mut p = GreedyCheapest::new();
        let first = p.select(&job, &ctx).market();
        p.on_revocation(&job, first, &ctx);
        assert_ne!(p.select(&job, &ctx).market(), first);
    }
}
