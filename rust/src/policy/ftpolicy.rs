//! The fault-tolerance baseline's provisioning policy ("F" in Fig. 1).
//!
//! Models the SpotOn-style approach the paper compares against: pick the
//! *cheapest* suitable spot market (recent average price), attach a
//! fault-tolerance mechanism, and on revocation simply move to the next
//! cheapest market.  No lifetime analysis, no correlation filtering —
//! the FT mechanism is expected to absorb revocations.

use super::{Ctx, Decision, Policy};
use crate::job::Job;

#[derive(Clone, Debug, Default)]
/// The paper's FT arm policy: cheapest suitable spot market, relying on its paired FT mechanism to absorb revocations.
pub struct FtSpotPolicy {
    /// markets already revoked for the current job (avoid immediate
    /// re-provisioning of a just-revoked market)
    banned: Vec<usize>,
}

impl FtSpotPolicy {
    /// A fresh FT-spot policy.
    pub fn new() -> Self {
        FtSpotPolicy::default()
    }
}

impl Policy for FtSpotPolicy {
    fn name(&self) -> &'static str {
        "ft-spot"
    }

    fn select(&mut self, job: &Job, ctx: &Ctx<'_>) -> Decision {
        let w = ctx.world;
        let lookback = 24.0f64;
        let mut best: Option<(usize, f32)> = None;
        for id in w.catalog.suitable(job.mem_gb) {
            if self.banned.contains(&id) {
                continue;
            }
            let m = w.market(id);
            let p = m.mean_price((ctx.now - lookback).max(0.0), ctx.now.max(1.0));
            match best {
                Some((_, bp)) if bp <= p => {}
                _ => best = Some((id, p)),
            }
        }
        match best {
            Some((id, _)) => Decision::Spot { market: id },
            None => {
                // every suitable market revoked at least once: clear the
                // ban list and retry (the FT approach just keeps going)
                self.banned.clear();
                let id = ctx
                    .world
                    .catalog
                    .suitable(job.mem_gb)
                    .into_iter()
                    .next()
                    .expect("no suitable market");
                Decision::Spot { market: id }
            }
        }
    }

    fn on_revocation(&mut self, _job: &Job, market: usize, _ctx: &Ctx<'_>) {
        if !self.banned.contains(&market) {
            self.banned.push(market);
        }
    }

    fn reset(&mut self) {
        self.banned.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::world::World;

    #[test]
    fn picks_cheapest_suitable_spot() {
        let w = World::generate(48, 0.25, 5);
        let ctx = Ctx { world: &w, now: 24.0 };
        let job = Job::new(1, 8.0, 16.0);
        let mut p = FtSpotPolicy::new();
        let d = p.select(&job, &ctx);
        assert!(d.is_spot());
        let chosen = d.market();
        assert!(w.catalog.markets[chosen].instance.mem_gb >= 16.0);
        // verify minimality over the suitable set
        let price = |id: usize| w.market(id).mean_price(0.0, 24.0);
        for id in w.catalog.suitable(16.0) {
            assert!(price(chosen) <= price(id) + 1e-6);
        }
    }

    #[test]
    fn revoked_markets_avoided_then_recycled() {
        let w = World::generate(12, 0.25, 6);
        let ctx = Ctx { world: &w, now: 10.0 };
        let job = Job::new(1, 8.0, 16.0);
        let mut p = FtSpotPolicy::new();
        let suitable = w.catalog.suitable(16.0);
        let first = p.select(&job, &ctx).market();
        p.on_revocation(&job, first, &ctx);
        let second = p.select(&job, &ctx).market();
        if suitable.len() > 1 {
            assert_ne!(first, second);
        }
        // ban everything → policy recycles rather than deadlocking
        for &id in &suitable {
            p.on_revocation(&job, id, &ctx);
        }
        let d = p.select(&job, &ctx);
        assert!(d.is_spot());
    }

    #[test]
    fn reset_clears_bans() {
        let w = World::generate(12, 0.25, 7);
        let ctx = Ctx { world: &w, now: 5.0 };
        let job = Job::new(1, 4.0, 8.0);
        let mut p = FtSpotPolicy::new();
        p.on_revocation(&job, 0, &ctx);
        assert!(!p.banned.is_empty());
        p.reset();
        assert!(p.banned.is_empty());
    }
}
