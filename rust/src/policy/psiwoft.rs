//! P-SIWOFT — Algorithm 1 of the paper, faithfully.
//!
//! Steps (numbers match the paper's listing):
//!  2. `FindSuitableServers`  — memory-suitable markets (catalog).
//!  3. `ComputeLifeTime`      — per-market MTTR from the trace window
//!                              (the analytics artifact / native mirror).
//!  5. `ServerBasedLifeTime`  — restrict to suitable markets, sort by
//!                              lifetime descending.
//!  7. `Highest`              — pick the highest-MTTR candidate.
//!  8. `length(s) >> length(j)` — require MTTR ≥ 2 × job length
//!                              (the paper's "at least twice").
//!  9. `RevocationProbability` — p = job_length / MTTR (exposed for
//!                              metrics/inspection).
//! 13. `FindLowCorrelation`   — after a revocation, keep only markets
//!                              whose revocation correlation with the
//!                              revoked one is below a threshold.
//! 14. `S ← (S \ {s}) ∩ W`    — shrink the candidate set.
//!
//! Where the paper leaves behaviour undefined — the candidate set runs
//! empty, or no market passes the 2× lifetime test — we fall back to the
//! cheapest suitable *on-demand* instance, consistent with the paper's
//! stated goal ("completion time near that of on-demand instances") and
//! its own observation that on-demand dominates FT in those regimes.

use super::{Ctx, Decision, Policy};
use crate::job::Job;
use crate::market::PlacementScores;

#[derive(Clone, Copy, Debug, PartialEq)]
/// Tunable thresholds of P-SIWOFT (Algorithm 1).
pub struct PSiwoftConfig {
    /// Step 8 margin: require MTTR ≥ `lifetime_factor` × job length.
    pub lifetime_factor: f64,
    /// Step 13 threshold: markets correlate "low" when below this.
    pub corr_threshold: f32,
    /// Ablation switch: disable the correlation filter (Step 13/14
    /// degenerate to just removing the revoked market).
    pub use_corr_filter: bool,
    /// Weight of the placement-score signal
    /// ([`MarketAnalytics::placement_scores`](crate::market::MarketAnalytics::placement_scores))
    /// in the tie-break among statistically-tied top-lifetime
    /// candidates.  `0.0` (the default) preserves the paper's pure
    /// lowest-price tie-break bit-for-bit; `w > 0` maximizes
    /// `w·score − (1−w)·price/od` instead, preferring markets whose
    /// revocation-adjusted packing value is high — the knob DAG/packing
    /// workloads turn on.  Clamped to `[0, 1]` at decision time.
    pub placement_weight: f64,
}

impl Default for PSiwoftConfig {
    fn default() -> Self {
        PSiwoftConfig {
            lifetime_factor: 2.0,
            corr_threshold: 0.2,
            use_corr_filter: true,
            placement_weight: 0.0,
        }
    }
}

#[derive(Clone, Debug)]
/// P-SIWOFT (Algorithm 1): the paper's provisioning policy.
pub struct PSiwoft {
    /// The configuration in force.
    pub cfg: PSiwoftConfig,
    /// S_j: candidate market set for the current job (None = not yet
    /// initialized for this job)
    candidates: Option<Vec<usize>>,
    /// last computed revocation probability (Step 9), for metrics
    pub last_revocation_prob: f64,
    /// decisions that fell back to on-demand
    pub ondemand_fallbacks: u64,
    /// placement scores cached per job (like `candidates`): the fit is a
    /// pure function of (analytics, catalog, job length), so one compute
    /// serves every session of the job
    placement: Option<PlacementScores>,
}

impl PSiwoft {
    /// A fresh policy with the given config.
    pub fn new(cfg: PSiwoftConfig) -> Self {
        PSiwoft {
            cfg,
            candidates: None,
            last_revocation_prob: 0.0,
            ondemand_fallbacks: 0,
            placement: None,
        }
    }

    /// Step 9: revocation probability of provisioning `market` for `job`.
    pub fn revocation_probability(job: &Job, mttr_h: f64) -> f64 {
        if mttr_h <= 0.0 {
            1.0
        } else {
            (job.exec_len_h / mttr_h).min(1.0)
        }
    }

    fn init_candidates(&mut self, job: &Job, ctx: &Ctx<'_>) -> &mut Vec<usize> {
        if self.candidates.is_none() {
            // Steps 2+3+5: suitable servers, lifetimes, sorted descending.
            let suitable = ctx.world.catalog.suitable(job.mem_gb);
            let sorted = ctx.world.analytics.sort_by_lifetime_desc(&suitable);
            self.candidates = Some(sorted);
        }
        self.candidates.as_mut().unwrap()
    }
}

impl Default for PSiwoft {
    fn default() -> Self {
        PSiwoft::new(PSiwoftConfig::default())
    }
}

impl Policy for PSiwoft {
    fn name(&self) -> &'static str {
        "p-siwoft"
    }

    fn select(&mut self, job: &Job, ctx: &Ctx<'_>) -> Decision {
        let factor = self.cfg.lifetime_factor;
        // clamp: w > 1 would flip the price term into a preference for
        // expensive markets
        let weight = self.cfg.placement_weight.clamp(0.0, 1.0);
        let analytics = &ctx.world.analytics;
        let candidates = self.init_candidates(job, ctx);

        // Step 7: highest-lifetime candidate (list is kept sorted desc).
        // The paper's `Highest` doesn't define tie-breaks; in practice a
        // large fraction of markets never revoke inside the window so
        // their MTTR estimates saturate at (or near) the window length
        // and are statistically indistinguishable (a window with ≤ 1
        // revocation event pins the estimate).  We treat candidates
        // within a day (or 2 %) of the top lifetime as tied and break
        // the tie economically: lowest current spot price — or, with
        // `placement_weight > 0`, by the blended placement-score key
        // (revocation-adjusted packing value vs. normalized price).
        if let Some(&first) = candidates.first() {
            let top_mttr = analytics.mttr[first];
            let cutoff = top_mttr - (top_mttr * 0.02).max(24.0);
            let t0 = (ctx.now - 24.0).max(0.0);
            let t1 = ctx.now.max(t0 + 1.0);
            // collected so the candidate borrow ends before the
            // placement cache (also `&mut self`) is touched below
            let tied: Vec<usize> =
                candidates.iter().copied().take_while(|&m| analytics.mttr[m] >= cutoff).collect();
            let best = if weight > 0.0 {
                let scores = self.placement.get_or_insert_with(|| {
                    analytics.placement_scores(&ctx.world.catalog, job.exec_len_h)
                });
                let key = |m: usize| {
                    // trailing-day mean price normalized by od so it
                    // blends with the (0,1]-scaled placement score
                    let rel =
                        ctx.world.market(m).mean_price(t0, t1) as f64 / ctx.world.od_price(m);
                    weight * scores.at(m) as f64 - (1.0 - weight) * rel
                };
                tied.into_iter()
                    .max_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap().then(b.cmp(&a)))
                    .unwrap_or(first)
            } else {
                tied.into_iter()
                    .min_by(|&a, &b| {
                        // trailing-day mean price: robust to single-hour noise
                        let pa = ctx.world.market(a).mean_price(t0, t1);
                        let pb = ctx.world.market(b).mean_price(t0, t1);
                        pa.partial_cmp(&pb).unwrap().then(a.cmp(&b))
                    })
                    .unwrap_or(first)
            };
            let mttr = analytics.mttr[best] as f64;
            // Step 8: lifetime must comfortably exceed the job.
            if mttr >= factor * job.exec_len_h {
                self.last_revocation_prob = Self::revocation_probability(job, mttr);
                return Decision::Spot { market: best };
            }
        }
        // Fallback: no candidate passes the lifetime test → on-demand.
        self.ondemand_fallbacks += 1;
        let od = ctx
            .world
            .catalog
            .cheapest_ondemand(job.mem_gb)
            .expect("catalog has no market fitting the job");
        Decision::OnDemand { market: od }
    }

    fn on_revocation(&mut self, job: &Job, market: usize, ctx: &Ctx<'_>) {
        let cfg = self.cfg;
        let analytics = &ctx.world.analytics;
        let candidates = self.init_candidates(job, ctx);
        // Step 14: S ← (S \ {s}) ∩ W.
        candidates.retain(|&m| m != market);
        if cfg.use_corr_filter {
            // Step 13: W = low-correlation set w.r.t. the revoked market.
            candidates.retain(|&m| analytics.corr_at(market, m) < cfg.corr_threshold);
        }
    }

    fn reset(&mut self) {
        self.candidates = None;
        self.placement = None;
        self.last_revocation_prob = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::{Catalog, PriceTrace};
    use crate::sim::world::World;

    /// World with hand-crafted trace.  For a 16 GB job the best-fit
    /// suitable type is r5.large, whose markets in the 64-market catalog
    /// (16 types × us-east-1{a,b,c} + 16 × us-west-2a) are ids 12, 28,
    /// 44, 60.  Markets 12 and 28 revoke together every 4 h (low MTTR,
    /// corr 1); 44 and 60 never revoke (MTTR = window).
    const TWIN_A: usize = 12;
    const TWIN_B: usize = 28;
    const STABLE: usize = 44;

    fn rigged_world() -> World {
        let catalog = Catalog::with_limit(64);
        let hours = 64usize;
        let mut rows = Vec::new();
        for m in 0..64 {
            let od = catalog.markets[m].od_price as f32;
            let row: Vec<f32> = (0..hours)
                .map(|h| {
                    let spike = match m {
                        TWIN_A | TWIN_B => h % 4 == 3,
                        _ => false,
                    };
                    if spike {
                        od * 1.5
                    } else {
                        od * 0.3
                    }
                })
                .collect();
            rows.push(row);
        }
        World::new(catalog, PriceTrace::from_rows(rows).unwrap())
    }

    #[test]
    fn selects_highest_mttr_first() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 8.0, 16.0);
        let mut p = PSiwoft::default();
        let d = p.select(&job, &ctx);
        assert!(d.is_spot());
        // must be the never-revoking suitable market (MTTR = 64)
        assert_eq!(d.market(), STABLE);
        assert_eq!(w.analytics.mttr[d.market()], 64.0);
        assert!(p.last_revocation_prob <= 8.0 / 64.0 + 1e-9);
    }

    #[test]
    fn respects_twice_lifetime_rule() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        // job longer than half the best MTTR → must fall back to on-demand
        let job = Job::new(1, 40.0, 16.0);
        let mut p = PSiwoft::default();
        let d = p.select(&job, &ctx);
        assert!(!d.is_spot());
        assert_eq!(p.ondemand_fallbacks, 1);
    }

    #[test]
    fn revocation_removes_market_and_correlated_ones() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 2.0, 16.0);
        let mut p = PSiwoft::default();
        let _ = p.select(&job, &ctx);
        // suppose TWIN_A was (hypothetically) provisioned and revoked:
        p.on_revocation(&job, TWIN_A, &ctx);
        let cands = p.candidates.clone().unwrap();
        assert!(!cands.contains(&TWIN_A), "revoked market still a candidate");
        assert!(!cands.contains(&TWIN_B), "perfectly correlated market kept");
        assert!(cands.contains(&STABLE), "uncorrelated market dropped");
    }

    #[test]
    fn corr_filter_ablation() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 2.0, 16.0);
        let mut p = PSiwoft::new(PSiwoftConfig { use_corr_filter: false, ..Default::default() });
        let _ = p.select(&job, &ctx);
        p.on_revocation(&job, TWIN_A, &ctx);
        let cands = p.candidates.clone().unwrap();
        assert!(!cands.contains(&TWIN_A));
        assert!(cands.contains(&TWIN_B), "without the filter, the twin stays");
    }

    #[test]
    fn placement_weight_tiebreak_stays_on_top_lifetime_candidates() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 8.0, 16.0);
        let mut p = PSiwoft::new(PSiwoftConfig { placement_weight: 0.8, ..Default::default() });
        let d = p.select(&job, &ctx);
        assert!(d.is_spot());
        // the two never-revoking r5.large markets are score-tied (same
        // type, price, MTTR); the deterministic lowest-id tie-break must
        // keep the selection inside the top-lifetime set
        assert_eq!(d.market(), STABLE);
        assert_eq!(w.analytics.mttr[d.market()], 64.0);
    }

    #[test]
    fn reset_clears_state() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 2.0, 16.0);
        let mut p = PSiwoft::default();
        let _ = p.select(&job, &ctx);
        p.on_revocation(&job, 0, &ctx);
        p.reset();
        let _ = p.select(&job, &ctx);
        assert!(p.candidates.as_ref().unwrap().len() > 1);
    }

    #[test]
    fn revocation_probability_formula() {
        let job = Job::new(1, 8.0, 16.0);
        assert!((PSiwoft::revocation_probability(&job, 64.0) - 0.125).abs() < 1e-12);
        assert_eq!(PSiwoft::revocation_probability(&job, 4.0), 1.0); // capped
        assert_eq!(PSiwoft::revocation_probability(&job, 0.0), 1.0);
    }

    #[test]
    fn exhausted_candidates_fall_back() {
        let w = rigged_world();
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 2.0, 16.0);
        let mut p = PSiwoft::default();
        let _ = p.select(&job, &ctx);
        // revoke everything
        let all: Vec<usize> = (0..w.n_markets()).collect();
        for m in all {
            p.on_revocation(&job, m, &ctx);
        }
        let d = p.select(&job, &ctx);
        assert!(!d.is_spot());
    }
}
