//! Provisioning policies: P-SIWOFT (Algorithm 1) and the baselines it is
//! evaluated against (fault-tolerance spot policy, on-demand, and a
//! lifetime-blind greedy ablation).
//!
//! A policy answers one question — *which market gets the next
//! (re)provisioning of this job?* — given the world's analytics and the
//! job's revocation history.  Policies are per-job stateful (`reset`
//! clears the candidate-set state between jobs).

pub mod ftpolicy;
pub mod greedy;
pub mod ondemand;
pub mod predictive;
pub mod psiwoft;

pub use ftpolicy::FtSpotPolicy;
pub use greedy::GreedyCheapest;
pub use ondemand::OnDemandPolicy;
pub use predictive::{PredictiveConfig, PredictivePolicy};
pub use psiwoft::{PSiwoft, PSiwoftConfig};

use crate::job::Job;
use crate::sim::world::World;

/// Provisioning decision for the next session.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// rent this spot market (paying its spot price)
    Spot { market: usize },
    /// rent an on-demand instance in this market (paying od price,
    /// never revoked)
    OnDemand { market: usize },
}

impl Decision {
    /// The market this decision provisions in.
    pub fn market(&self) -> usize {
        match *self {
            Decision::Spot { market } | Decision::OnDemand { market } => market,
        }
    }
    /// True for spot decisions.
    pub fn is_spot(&self) -> bool {
        matches!(self, Decision::Spot { .. })
    }
}

/// Context handed to a policy at decision time.
pub struct Ctx<'a> {
    /// The world (markets, prices, analytics) at decision time.
    pub world: &'a World,
    /// current simulation time (hours into the trace window)
    pub now: f64,
}

/// A provisioning policy: chooses markets, observes revocations.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Choose where to (re)provision `job`.
    fn select(&mut self, job: &Job, ctx: &Ctx<'_>) -> Decision;

    /// Observe a revocation of `market` while running `job` (updates
    /// candidate-set state; called before the next `select`).
    fn on_revocation(&mut self, job: &Job, market: usize, ctx: &Ctx<'_>) {
        let _ = (job, market, ctx);
    }

    /// Clear per-job state (called when a new job begins).
    fn reset(&mut self);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_accessors() {
        let d = Decision::Spot { market: 3 };
        assert_eq!(d.market(), 3);
        assert!(d.is_spot());
        let d = Decision::OnDemand { market: 5 };
        assert_eq!(d.market(), 5);
        assert!(!d.is_spot());
    }
}
