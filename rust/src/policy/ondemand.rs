//! On-demand baseline ("O" in Fig. 1): cheapest suitable on-demand
//! instance, never revoked, no FT overhead — the completion-time gold
//! standard the paper normalizes against (and the cost ceiling spot
//! provisioning tries to undercut).

use super::{Ctx, Decision, Policy};
use crate::job::Job;

#[derive(Clone, Copy, Debug, Default)]
/// On-demand baseline: never touches the spot market.
pub struct OnDemandPolicy;

impl Policy for OnDemandPolicy {
    fn name(&self) -> &'static str {
        "on-demand"
    }

    fn select(&mut self, job: &Job, ctx: &Ctx<'_>) -> Decision {
        let market = ctx
            .world
            .catalog
            .cheapest_ondemand(job.mem_gb)
            .expect("no market fits the job");
        Decision::OnDemand { market }
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::world::World;

    #[test]
    fn always_ondemand_and_cheapest() {
        let w = World::generate(48, 0.25, 8);
        let ctx = Ctx { world: &w, now: 0.0 };
        let job = Job::new(1, 8.0, 16.0);
        let mut p = OnDemandPolicy;
        let d = p.select(&job, &ctx);
        assert!(!d.is_spot());
        let chosen = d.market();
        for id in w.catalog.suitable(16.0) {
            assert!(w.od_price(chosen) <= w.od_price(id) + 1e-12);
        }
    }
}
