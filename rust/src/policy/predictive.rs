//! Predictive provisioning baseline: survival-probability maximization.
//!
//! Implements the duration-probability approach of the paper's related
//! work (ref.\[17\], Wolski et al.): instead of P-SIWOFT's point-estimate MTTR
//! ordering, rank candidate markets by the *empirical probability of
//! surviving the whole job* (`S[m, job_length]` from the survival
//! artifact / native mirror), require it to clear a confidence floor,
//! and break near-ties by price.  On revocation, drop the market (no
//! correlation filter — that is P-SIWOFT's contribution).
//!
//! This gives the evaluation a second analytics-driven arm, isolating
//! how much of P-SIWOFT's win is "use market statistics at all" versus
//! its specific MTTR + correlation recipe.

use super::{Ctx, Decision, Policy};
use crate::job::Job;
use crate::market::analytics::SurvivalCurves;
use crate::market::PlacementScores;

#[derive(Clone, Copy, Debug, PartialEq)]
/// Knobs of the survival-probability baseline (ref. \[17\]).
pub struct PredictiveConfig {
    /// minimum acceptable survival probability over the job length
    pub confidence: f32,
    /// near-tie band for the price tie-break
    pub tie_band: f32,
    /// Weight of the placement-score signal
    /// ([`MarketAnalytics::placement_scores`](crate::market::MarketAnalytics::placement_scores))
    /// in the near-tie selection.  `0.0` (the default) keeps the pure
    /// cheapest-price tie-break; `w > 0` maximizes
    /// `w·score − (1−w)·price/od` among the tie-band candidates.
    /// Clamped to `[0, 1]` at decision time.
    pub placement_weight: f32,
}

impl Default for PredictiveConfig {
    fn default() -> Self {
        PredictiveConfig { confidence: 0.7, tie_band: 0.05, placement_weight: 0.0 }
    }
}

/// Survival-probability policy: pick the cheapest market whose curve clears the confidence bar over the job length.
pub struct PredictivePolicy {
    /// The configuration in force.
    pub cfg: PredictiveConfig,
    curves: SurvivalCurves,
    banned: Vec<usize>,
    /// Decisions that fell back to on-demand.
    pub ondemand_fallbacks: u64,
    /// placement scores cached per job (pure function of analytics ×
    /// catalog × horizon; recomputing per select would rebuild an
    /// O(markets) vector every session)
    placement: Option<PlacementScores>,
}

impl PredictivePolicy {
    /// Build from precomputed survival curves (native or PJRT — the
    /// policy is agnostic, mirroring how `PSiwoft` reads `World::analytics`).
    pub fn new(curves: SurvivalCurves, cfg: PredictiveConfig) -> Self {
        PredictivePolicy {
            cfg,
            curves,
            banned: Vec::new(),
            ondemand_fallbacks: 0,
            placement: None,
        }
    }

    /// Train curves on `world`'s trace with default config.
    pub fn from_world(world: &crate::sim::World) -> Self {
        let curves =
            SurvivalCurves::compute(&world.trace, &world.od, SurvivalCurves::DEFAULT_T);
        PredictivePolicy::new(curves, PredictiveConfig::default())
    }

    /// Survival curves computed on a training prefix of the world's trace.
    pub fn from_world_trained(world: &crate::sim::World, train_hours: usize) -> Self {
        let train = world.trace.window(0, train_hours);
        let curves = SurvivalCurves::compute(&train, &world.od, SurvivalCurves::DEFAULT_T);
        PredictivePolicy::new(curves, PredictiveConfig::default())
    }
}

impl Policy for PredictivePolicy {
    fn name(&self) -> &'static str {
        "predictive-survival"
    }

    fn select(&mut self, job: &Job, ctx: &Ctx<'_>) -> Decision {
        let horizon = job.exec_len_h;
        let candidates: Vec<usize> = ctx
            .world
            .catalog
            .suitable(job.mem_gb)
            .into_iter()
            .filter(|m| !self.banned.contains(m))
            .collect();
        let ranked = self.curves.rank_by_survival(&candidates, horizon);
        if let Some(&best) = ranked.first() {
            let s_best = self.curves.at(best, horizon);
            if s_best >= self.cfg.confidence {
                // near-tie band → cheapest by trailing-day mean price,
                // or the blended placement-score key when enabled
                let t0 = (ctx.now - 24.0).max(0.0);
                let t1 = ctx.now.max(t0 + 1.0);
                // clamp: w > 1 would flip the price term into a
                // preference for expensive markets
                let weight = (self.cfg.placement_weight as f64).clamp(0.0, 1.0);
                // collected so the curves borrow ends before the
                // placement cache (also `&mut self`) is touched below
                let tied: Vec<usize> = ranked
                    .iter()
                    .copied()
                    .take_while(|&m| self.curves.at(m, horizon) >= s_best - self.cfg.tie_band)
                    .collect();
                let chosen = if weight > 0.0 {
                    let scores = self.placement.get_or_insert_with(|| {
                        ctx.world.analytics.placement_scores(&ctx.world.catalog, horizon)
                    });
                    let key = |m: usize| {
                        let rel = ctx.world.market(m).mean_price(t0, t1) as f64
                            / ctx.world.od_price(m);
                        weight * scores.at(m) as f64 - (1.0 - weight) * rel
                    };
                    tied.into_iter()
                        .max_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap().then(b.cmp(&a)))
                        .unwrap_or(best)
                } else {
                    tied.into_iter()
                        .min_by(|&a, &b| {
                            let pa = ctx.world.market(a).mean_price(t0, t1);
                            let pb = ctx.world.market(b).mean_price(t0, t1);
                            pa.partial_cmp(&pb).unwrap().then(a.cmp(&b))
                        })
                        .unwrap_or(best)
                };
                return Decision::Spot { market: chosen };
            }
        }
        self.ondemand_fallbacks += 1;
        let od = ctx
            .world
            .catalog
            .cheapest_ondemand(job.mem_gb)
            .expect("no market fits the job");
        Decision::OnDemand { market: od }
    }

    fn on_revocation(&mut self, _job: &Job, market: usize, _ctx: &Ctx<'_>) {
        if !self.banned.contains(&market) {
            self.banned.push(market);
        }
    }

    fn reset(&mut self) {
        self.banned.clear();
        self.placement = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{PolicyKind, Scenario};
    use crate::sim::World;

    fn world() -> (World, f64) {
        let mut w = World::generate(96, 2.0, 808);
        let start = w.split_train(0.6);
        (w, start)
    }

    #[test]
    fn selects_high_survival_market() {
        let (w, start) = world();
        let job = Job::new(1, 8.0, 16.0);
        let mut p = PredictivePolicy::from_world_trained(&w, start as usize);
        let d = p.select(&job, &Ctx { world: &w, now: start });
        if d.is_spot() {
            let s = p.curves.at(d.market(), 8.0);
            // chosen market clears the confidence floor
            assert!(s >= p.cfg.confidence, "s = {s}");
            // and no candidate beats it by more than the tie band
            for m in w.catalog.suitable(16.0) {
                assert!(p.curves.at(m, 8.0) <= s + p.cfg.tie_band + 1e-6);
            }
        } else {
            assert_eq!(p.ondemand_fallbacks, 1);
        }
    }

    #[test]
    fn placement_weight_path_is_deterministic_and_stays_in_band() {
        let (w, start) = world();
        let job = Job::new(5, 8.0, 16.0);
        let mut a = PredictivePolicy::from_world_trained(&w, start as usize);
        a.cfg.placement_weight = 0.7;
        let mut b = PredictivePolicy::from_world_trained(&w, start as usize);
        b.cfg.placement_weight = 0.7;
        let ctx = Ctx { world: &w, now: start };
        let da = a.select(&job, &ctx);
        assert_eq!(da, b.select(&job, &ctx));
        if da.is_spot() {
            // still a confident candidate: the score only re-ranks the band
            assert!(a.curves.at(da.market(), 8.0) >= a.cfg.confidence - a.cfg.tie_band);
        }
    }

    #[test]
    fn falls_back_when_confidence_unreachable() {
        let (w, start) = world();
        let job = Job::new(2, 8.0, 16.0);
        let mut p = PredictivePolicy::from_world_trained(&w, start as usize);
        p.cfg.confidence = 1.01; // impossible
        let d = p.select(&job, &Ctx { world: &w, now: start });
        assert!(!d.is_spot());
    }

    #[test]
    fn revoked_markets_banned_until_reset() {
        let (w, start) = world();
        let job = Job::new(3, 4.0, 16.0);
        let mut p = PredictivePolicy::from_world_trained(&w, start as usize);
        let ctx = Ctx { world: &w, now: start };
        let first = p.select(&job, &ctx);
        if first.is_spot() {
            p.on_revocation(&job, first.market(), &ctx);
            let second = p.select(&job, &ctx);
            if second.is_spot() {
                assert_ne!(second.market(), first.market());
            }
            p.reset();
            assert!(p.banned.is_empty());
        }
    }

    #[test]
    fn completes_jobs_end_to_end() {
        let (w, start) = world();
        let job = Job::new(4, 8.0, 16.0);
        let r = Scenario::on(&w)
            .job(job)
            .policy(PolicyKind::Predictive(PredictiveConfig::default()))
            .start_t(start)
            .seed(3)
            .run();
        assert!(r.completed);
        assert!(r.completion_h() >= 8.0);
    }
}
