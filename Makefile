# Convenience targets; everything also works as plain cargo/pytest
# invocations (see README.md).

.PHONY: build test test-rust test-python artifacts fig1 docs fmt lint lint-src

build:
	cd rust && cargo build --release

# `make test` lowers the AOT artifacts first (needs JAX).  Note the
# PJRT integration tests still skip unless the crate is built with
# `--features pjrt` + vendored xla bindings (DESIGN.md §5) — the
# artifacts alone are not enough.  Use `make test-rust` on a
# Python-less host.
test: artifacts test-rust test-python

test-rust:
	cd rust && cargo test -q

test-python:
	python -m pytest python/tests -q

artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

fig1:
	cd rust && cargo run --release -- fig1 --seed 2020 --format csv

docs:
	cd rust && cargo doc --no-deps

fmt:
	cd rust && cargo fmt

lint:
	cd rust && cargo clippy --all-targets -- -D warnings

# In-tree static-analysis pass (DESIGN.md §12) via the dependency-free
# Python mirror — works on hosts without a Rust toolchain.  The
# canonical implementation is `siwoft lint` (same rules, same fixture
# corpus: rust/tests/fixtures/lint/).
lint-src:
	python3 tools/lint_src.py --selfcheck
	python3 tools/lint_src.py --src rust/src
