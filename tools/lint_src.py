#!/usr/bin/env python3
"""Dependency-free mirror of `siwoft lint` for toolchain-less hosts.

The canonical linter is `rust/src/lint/` (run as `siwoft lint`); this
script re-implements the same scanner and rule catalog (DESIGN.md §12)
in ~stdlib Python so `make lint-src` works in containers that have no
cargo at all — including the container this repo is grown in.  Both
implementations are pinned to the fixture corpus under
`rust/tests/fixtures/lint/`: the Rust side by `tests/lint_selfcheck.rs`,
this side by `--selfcheck` (run in CI ahead of the toolchain jobs).

Findings are reported as (rule, file, line, msg) and the JSON document
uses the same schema_version=1 shape as the Rust reporter.  Exit status:
0 clean, 1 findings, 2 usage/IO error.
"""

import argparse
import json
import os
import re
import sys

SCHEMA_VERSION = 1
ALL_RULES = ["a1", "d1", "d2", "e1", "h1"]

RESULT_MODULES = [
    "sim", "dag", "service", "scenario", "policy", "ft", "job", "market", "pack",
    "session", "obs",
]
D1_TOKENS = [
    "SystemTime", "Instant::now", "std::time::Instant", "std::env", "HashMap", "HashSet",
]
D2_TOKENS = [
    "rand::", "thread_rng", "from_entropy", "getrandom", "RandomState", "DefaultHasher",
]
RELAXED_ALLOWLIST = ["counter", "reaped", "rejected", "peak_live", "self.next", "LEVEL"]
SAFETY_LOOKBACK = 8

H1_ITEM_PREFIXES = [
    "pub fn ", "pub unsafe fn ", "pub struct ", "pub enum ", "pub trait ",
    "pub unsafe trait ", "pub const ", "pub static ", "pub type ",
]


class Line:
    __slots__ = ("number", "code", "comment", "in_test", "is_doc", "depth")

    def __init__(self, number, code, comment, in_test, is_doc, depth):
        self.number = number
        self.code = code
        self.comment = comment
        self.in_test = in_test
        self.is_doc = is_doc
        self.depth = depth


def _char_literal_end(s, i):
    """Index of the closing quote of a char literal at s[i]=="'", else None."""
    if i + 1 >= len(s):
        return None
    c = s[i + 1]
    if c == "\\":
        j = i + 2
        while j < len(s) and j < i + 12:
            if s[j] == "'":
                return j
            j += 1
        return None
    if c == "'":
        return None
    if i + 2 < len(s) and s[i + 2] == "'":
        return i + 2
    return None


def scan_source(rel_path, text):
    """Mirror of lint/scan.rs scan_source: per-line (code, comment) split."""
    lines = []
    mode = "code"          # code | str | block | rawstr
    block_depth = 0
    block_doc = False
    raw_hashes = 0
    depth = 0
    test_pending = False
    test_until = None

    for idx, raw in enumerate(text.split("\n")):
        start_depth = depth
        in_test_at_start = test_until is not None or test_pending
        code = []
        comment = []
        is_doc = mode == "block" and block_doc

        i, n = 0, len(raw)
        while i < n:
            c = raw[i]
            nxt = raw[i + 1] if i + 1 < n else ""
            if mode == "block":
                if c == "/" and nxt == "*":
                    block_depth += 1
                    i += 2
                elif c == "*" and nxt == "/":
                    block_depth -= 1
                    if block_depth == 0:
                        mode = "code"
                    i += 2
                else:
                    comment.append(c)
                    i += 1
            elif mode == "rawstr":
                if c == '"' and raw[i + 1 : i + 1 + raw_hashes] == "#" * raw_hashes:
                    code.append('"')
                    i += 1 + raw_hashes
                    mode = "code"
                else:
                    code.append(" ")
                    i += 1
            elif mode == "str":
                if c == "\\":
                    code.append("  " if nxt else " ")
                    i += 2 if nxt else 1
                elif c == '"':
                    code.append('"')
                    mode = "code"
                    i += 1
                else:
                    code.append(" ")
                    i += 1
            else:  # code
                if c == "/" and nxt == "/":
                    third = raw[i + 2] if i + 2 < n else ""
                    is_doc = third in ("/", "!")
                    skip = 3 if is_doc else 2
                    comment.append(raw[i + skip :])
                    i = n
                elif c == "/" and nxt == "*":
                    third = raw[i + 2] if i + 2 < n else ""
                    doc = third in ("*", "!")
                    is_doc = is_doc or doc
                    mode, block_depth, block_doc = "block", 1, doc
                    i += 2
                elif (
                    c == "r"
                    and nxt in ('"', "#")
                    and not (i > 0 and (raw[i - 1].isalnum() or raw[i - 1] == "_"))
                ):
                    j = i + 1
                    hashes = 0
                    while j < n and raw[j] == "#":
                        hashes += 1
                        j += 1
                    if j < n and raw[j] == '"':
                        code.append('"')
                        mode, raw_hashes = "rawstr", hashes
                        i = j + 1
                    else:
                        code.append(c)
                        i += 1
                elif c == '"':
                    code.append('"')
                    mode = "str"
                    i += 1
                elif c == "'":
                    end = _char_literal_end(raw, i)
                    if end is not None:
                        code.append("'")
                        code.append(" " * (end - i - 1))
                        code.append("'")
                        i = end + 1
                    else:
                        code.append("'")
                        i += 1
                else:
                    if c == "{":
                        depth += 1
                        if test_pending:
                            test_pending = False
                            if test_until is None:
                                test_until = depth - 1
                    elif c == "}":
                        depth = max(0, depth - 1)
                        if test_until == depth:
                            test_until = None
                    code.append(c)
                    i += 1

        code = "".join(code)
        comment = "".join(comment)

        p = code.find("#[cfg(test)]")
        if p < 0:
            p = code.find("#[cfg(all(test")
        if p >= 0:
            if "{" in code[p:]:
                if test_until is None:
                    test_until = start_depth
            else:
                test_pending = True
        elif test_pending and test_until is None and code.strip().endswith(";"):
            test_pending = False

        lines.append(
            Line(
                idx + 1,
                code,
                comment,
                in_test_at_start or test_until is not None or test_pending,
                is_doc,
                start_depth,
            )
        )
    return rel_path, lines


# ---------------------------------------------------------------- rules

def is_result_module(rel):
    return any(rel.startswith(m + "/") or rel == m + ".rs" for m in RESULT_MODULES)


def a1_ordering_scope(rel):
    return rel.startswith("coordinator/") or rel == "util/logger.rs"


def has_comment_tag(lines, i, tag, lookback):
    lo = max(0, i - lookback)
    return any(tag in l.comment for l in lines[lo : i + 1])


def d1_rule(rel, lines, out):
    if not is_result_module(rel):
        return
    for l in lines:
        if l.in_test:
            continue
        for tok in D1_TOKENS:
            if tok in l.code:
                out.append(("d1", rel, l.number, f"determinism wall: `{tok}`"))


def d2_rule(rel, lines, out):
    if rel == "util/rng.rs":
        return
    for l in lines:
        if l.in_test:
            continue
        for tok in D2_TOKENS:
            if tok in l.code:
                out.append(("d2", rel, l.number, f"rng discipline: `{tok}`"))


def a1_rule(rel, lines, out):
    scope = a1_ordering_scope(rel)
    for i, l in enumerate(lines):
        if l.in_test:
            continue
        code = l.code.replace("cmp::Ordering", "")
        if scope and "Ordering::" in code:
            if not has_comment_tag(lines, i, "ordering:", 1):
                out.append(
                    ("a1", rel, l.number, "atomics audit: `Ordering::*` needs `// ordering:`")
                )
            if "Ordering::Relaxed" in code and not any(a in code for a in RELAXED_ALLOWLIST):
                out.append(
                    ("a1", rel, l.number, "atomics audit: Relaxed outside the counter allowlist")
                )
        if ("unsafe fn" in code or "unsafe impl" in code or "unsafe {" in code) and not (
            has_comment_tag(lines, i, "SAFETY", SAFETY_LOOKBACK)
        ):
            out.append(("a1", rel, l.number, "atomics audit: `unsafe` without `SAFETY:`"))


def _variant_count(lines, marker):
    for i, l in enumerate(lines):
        if not l.in_test and marker in l.code:
            n = 0
            for m in lines[i + 1 :]:
                if m.depth <= l.depth and m.code.strip():
                    break
                t = m.code.strip()
                if m.depth == l.depth + 1 and t and not t.startswith("#[") and t[0].isupper():
                    n += 1
            return l.number, n
    return 0, None


def _span_token_count(lines, start, end, token):
    for i, l in enumerate(lines):
        if not l.in_test and start in l.code:
            n = 0
            for m in lines[i:]:
                n += m.code.count(token)
                if end == "\n}":
                    closes = (
                        m.number > l.number
                        and m.depth == l.depth + 1
                        and m.code.strip() == "}"
                    )
                else:
                    closes = end in m.code
                if closes:
                    return l.number, n
            return l.number, n
    return 0, None


def _breakdown_len(lines):
    for l in lines:
        if l.in_test:
            continue
        p = l.code.find("vals: [f64;")
        if p >= 0:
            mt = re.match(r"\s*(\d+)", l.code[p + len("vals: [f64;") :])
            return l.number, int(mt.group(1)) if mt else None
    return 0, None


def e1_rule(files, out):
    acc = files.get("sim/accounting.rs")
    if acc is None:
        return
    counts = []
    ln, n = _variant_count(acc, "pub enum Category")
    counts.append(("Category variants", "sim/accounting.rs", ln, n))
    ln, n = _span_token_count(acc, "const CATEGORIES", "];", "Category::")
    counts.append(("CATEGORIES entries", "sim/accounting.rs", ln, n))
    ln, n = _breakdown_len(acc)
    counts.append(("Breakdown array length", "sim/accounting.rs", ln, n))
    tab = files.get("experiments/tables.rs")
    if tab is not None:
        ln, n = _span_token_count(tab, "fn glyph", "\n}", "Category::")
        counts.append(("tables glyph arms", "experiments/tables.rs", ln, n))
    for what, rel, ln, n in counts:
        if n is None:
            out.append(("e1", rel, ln, f"exhaustiveness: could not locate {what}"))
    known = [(w, rel, ln, n) for w, rel, ln, n in counts if n is not None]
    if known:
        first = known[0][3]
        for what, rel, ln, n in known:
            if n != first:
                out.append(
                    (
                        "e1",
                        rel,
                        ln,
                        f"exhaustiveness: {what} = {n} but {known[0][0]} = {first}",
                    )
                )


def _has_doc_above(lines, i):
    j = i - 1
    while j >= 0:
        l = lines[j]
        t = l.code.strip()
        if l.is_doc:
            return True
        if t.startswith("#[") or not t:
            j -= 1
            continue
        return False
    return False


def _module_doc(lines):
    for l in lines:
        if l.code.strip() or l.comment:
            return l.is_doc
    return False


def h1_rule(rel, lines, files, module_docs, sections, out):
    if rel == "main.rs":
        return
    for i, l in enumerate(lines):
        if l.in_test:
            continue
        t = l.code.strip()
        if t.startswith("pub mod ") and t.endswith(";"):
            name = t[len("pub mod ") : -1].strip()
            d = rel.rfind("/")
            prefix = rel[: d + 1] if d >= 0 else ""
            cands = [f"{prefix}{name}.rs", f"{prefix}{name}/mod.rs"]
            if not _has_doc_above(lines, i) and not any(
                module_docs.get(c, False) for c in cands
            ):
                out.append(("h1", rel, l.number, f"doc hygiene: missing rustdoc on public module `{name}`"))
            continue
        for prefix in H1_ITEM_PREFIXES:
            if t.startswith(prefix):
                if not _has_doc_above(lines, i):
                    name = re.match(r"[A-Za-z0-9_]*", t[len(prefix) :]).group(0)
                    out.append(("h1", rel, l.number, f"doc hygiene: missing rustdoc on public item `{name}`"))
                break
        is_struct = t.startswith("pub struct ")
        is_enum = t.startswith("pub enum ")
        if (is_struct or is_enum) and i + 1 < len(lines) and lines[i + 1].depth > l.depth:
            for m in lines[i + 1 :]:
                if m.depth <= l.depth and m.code.strip():
                    break
                if m.depth != l.depth + 1 or m.in_test:
                    continue
                mt = m.code.strip()
                midx = m.number - 1
                if is_struct and mt.startswith("pub "):
                    rest = mt[4:]
                    name = re.match(r"[A-Za-z0-9_]*", rest).group(0)
                    if rest[len(name) :].lstrip().startswith(":") and not _has_doc_above(lines, midx):
                        out.append(("h1", rel, m.number, f"doc hygiene: missing rustdoc on public field `{name}`"))
                elif is_enum and mt and not mt.startswith("#[") and mt[0].isupper():
                    if not _has_doc_above(lines, midx):
                        name = re.match(r"[A-Za-z0-9_]*", mt).group(0)
                        out.append(("h1", rel, m.number, f"doc hygiene: missing rustdoc on enum variant `{name}`"))
    if sections is not None:
        for l in lines:
            for mt in re.finditer(r"DESIGN\.md §([A-Za-z0-9_-]+)", l.comment):
                if mt.group(1) not in sections:
                    out.append(
                        ("h1", rel, l.number, f"doc hygiene: reference to DESIGN.md §{mt.group(1)} does not resolve")
                    )


# --------------------------------------------------------------- driver

def collect_pragmas(files, findings):
    allows = []
    for rel, lines in files.items():
        for l in lines:
            if l.is_doc:  # pragmas live in plain `//` comments only
                continue
            p = l.comment.find("siwoft-lint:")
            if p < 0:
                continue
            rest = l.comment[p + len("siwoft-lint:") :].lstrip()
            mt = re.match(r"allow\(([^)]*)\)", rest)
            if not mt:
                findings.append(("p1", rel, l.number, "malformed lint pragma: expected `allow(<rule>, <reason>)`"))
                continue
            args = mt.group(1)
            if "," not in args:
                findings.append(("p1", rel, l.number, "malformed lint pragma: missing `, <reason>`"))
                continue
            rule, reason = args.split(",", 1)
            rule = rule.strip().lower()
            if rule not in ALL_RULES:
                findings.append(("p1", rel, l.number, f"malformed lint pragma: unknown rule id `{rule}`"))
                continue
            if not reason.strip():
                findings.append(("p1", rel, l.number, "malformed lint pragma: empty reason"))
                continue
            allows.append((rel, l.number, rule))
    return allows


def design_sections(src):
    d = os.path.abspath(src)
    for _ in range(3):
        cand = os.path.join(d, "DESIGN.md")
        if os.path.isfile(cand):
            ids = []
            with open(cand, encoding="utf-8") as fh:
                for line in fh:
                    if not line.startswith("#"):
                        continue
                    t = line.lstrip("#").lstrip()
                    if t.startswith("§"):
                        mt = re.match(r"§([A-Za-z0-9_-]+)", t)
                        if mt:
                            ids.append(mt.group(1))
            return ids
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent
    return None


def run_lint(src, rules):
    files = {}
    for root, dirs, names in os.walk(src):
        dirs.sort()
        for name in sorted(names):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(root, name)
            rel = os.path.relpath(path, src).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                _, lines = scan_source(rel, fh.read())
            files[rel] = lines

    sections = design_sections(src)
    module_docs = {rel: _module_doc(lines) for rel, lines in files.items()}

    findings = []
    for rel in sorted(files):
        lines = files[rel]
        if "d1" in rules:
            d1_rule(rel, lines, findings)
        if "d2" in rules:
            d2_rule(rel, lines, findings)
        if "a1" in rules:
            a1_rule(rel, lines, findings)
        if "h1" in rules:
            h1_rule(rel, lines, files, module_docs, sections, findings)
    if "e1" in rules:
        e1_rule(files, findings)

    pragma_findings = []
    allows = collect_pragmas(files, pragma_findings)
    kept = [
        f
        for f in findings
        if not any(
            a[0] == f[1] and a[2] == f[0] and a[1] in (f[2], f[2] - 1) for a in allows
        )
    ]
    kept.extend(pragma_findings)
    kept.sort(key=lambda f: (f[1], f[2], f[0]))
    return kept, len(files)


def selfcheck(fixtures_root):
    """Run each rule against the planted fixture corpus; return failures."""
    expect_path = os.path.join(fixtures_root, "expected.json")
    with open(expect_path, encoding="utf-8") as fh:
        expected = json.load(fh)
    failures = []
    for case, want in sorted(expected.items()):
        case_dir = os.path.join(fixtures_root, case)
        got, _ = run_lint(case_dir, ALL_RULES)
        got_keys = [[f[0], f[1], f[2]] for f in got]
        if got_keys != want:
            failures.append(f"{case}: expected {want}, got {got_keys}")
    return failures


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--src", default="", help="source root (default: rust/src, else src)")
    ap.add_argument("--format", default="text", choices=["text", "json"])
    ap.add_argument("--rules", default="", help="comma-separated subset of d1,d2,a1,e1,h1")
    ap.add_argument(
        "--selfcheck",
        action="store_true",
        help="run the fixture corpus under rust/tests/fixtures/lint instead of --src",
    )
    args = ap.parse_args(argv)

    if args.selfcheck:
        root = args.src or "rust/tests/fixtures/lint"
        failures = selfcheck(root)
        if failures:
            for f in failures:
                print(f"selfcheck FAIL: {f}")
            return 1
        print("lint_src selfcheck: fixture corpus OK")
        return 0

    src = args.src
    if not src:
        src = "rust/src" if os.path.isdir("rust/src") else "src"
    if not os.path.isdir(src):
        print(f"lint_src: source root {src!r} not found", file=sys.stderr)
        return 2

    rules = ALL_RULES if not args.rules else []
    if args.rules:
        for rid in args.rules.split(","):
            rid = rid.strip().lower()
            if not rid:
                continue
            if rid not in ALL_RULES:
                print(f"lint_src: unknown rule {rid!r}", file=sys.stderr)
                return 2
            rules.append(rid)

    findings, files_scanned = run_lint(src, rules)
    if args.format == "json":
        doc = {
            "tool": "siwoft-lint",
            "schema_version": SCHEMA_VERSION,
            "rules": sorted(set(rules)),
            "files_scanned": files_scanned,
            "findings": [
                {"rule": r, "file": f, "line": ln, "msg": m} for r, f, ln, m in findings
            ],
        }
        print(json.dumps(doc, indent=2))
    else:
        for r, f, ln, m in findings:
            print(f"{f}:{ln}: [{r}] {m}")
        n = len(findings)
        print(
            f"siwoft lint: {n} finding{'s' if n != 1 else ''} in "
            f"{files_scanned} file{'s' if files_scanned != 1 else ''} "
            f"(rules: {','.join(sorted(set(rules)))})"
        )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
