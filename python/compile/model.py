"""Layer-2 JAX model: the market-analytics compute graph.

Composes the Layer-1 Pallas kernels into the single jitted function that
``aot.py`` lowers to an HLO artifact.  The Rust coordinator calls this
artifact once per *analytics epoch* (e.g. each simulated hour tick, or
once per trace refresh) — never per provisioning decision — so all the
per-market statistics P-SIWOFT consumes (MTTR, revocation counts,
correlation) come out of one PJRT execution over the raw price traces.

Signature (all f32):
    market_analytics(prices[M, H], ondemand[M])
        -> (mttr[M], events[M], frac_above[M], corr[M, M])

Semantics are pinned by ``kernels/ref.py`` and mirrored bit-for-bit by
``rust/src/market/analytics.rs``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import corr as corr_k
from .kernels import indicators as ind_k


def market_analytics(prices: jnp.ndarray, ondemand: jnp.ndarray):
    """Full analytics pipeline over one price-trace window."""
    x = ind_k.indicator_matrix(prices, ondemand)
    mttr, events, frac_above = ind_k.row_stats(x)
    c = corr_k.revocation_correlation(x)
    return mttr, events, frac_above, c


def survival_model(prices: jnp.ndarray, ondemand: jnp.ndarray):
    """Survival-curve pipeline (second artifact): S[M, T=64].

    Consumed by the Rust `policy::predictive` baseline — the
    duration-probability approach of the paper's related work [17].
    """
    from .kernels import survival as surv_k

    x = ind_k.indicator_matrix(prices, ondemand)
    return (surv_k.survival_matrix(x, surv_k.DEFAULT_T),)
