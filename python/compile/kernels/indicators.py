"""Pallas kernels: revocation indicators and per-market row statistics.

Layer-1 of the stack.  These kernels compute, from the hourly spot-price
matrix ``P[M, H]`` and on-demand price vector ``od[M]``:

  * the revocation-indicator matrix ``X[M, H]``,
  * per-market (mttr, events, frac_above) row statistics.

TPU shaping: each grid step owns a ``(bm, H)`` row-band of the trace in
VMEM (bm=128, H=2160 → ~1.1 MB per operand band, far under the ~16 MB
VMEM budget), performing the compare, the transition detection (a shift
along H) and the row reductions in a single HBM pass.  ``interpret=True``
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, and
correctness is validated through the interpret path (see DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT target; real-TPU lowering is compile-only.


def pick_block(m: int, preferred: int = 128) -> int:
    """Largest power-of-two block ≤ ``preferred`` that divides ``m``.

    Falls back to ``m`` itself (single block) for awkward sizes so that
    arbitrary market counts work in tests.
    """
    b = preferred
    while b > 1:
        if m % b == 0:
            return b
        b //= 2
    return m if m > 0 else 1


def _indicator_kernel(p_ref, od_ref, x_ref):
    """x = (p > od) over one (bm, H) row band."""
    p = p_ref[...]
    od = od_ref[...]
    x_ref[...] = (p > od[:, None]).astype(jnp.float32)


def indicator_matrix(prices: jnp.ndarray, ondemand: jnp.ndarray) -> jnp.ndarray:
    """Pallas version of ref.indicator_matrix (f32[M,H] → f32[M,H])."""
    m, h = prices.shape
    bm = pick_block(m)
    grid = (m // bm,)
    return pl.pallas_call(
        _indicator_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h), jnp.float32),
        interpret=INTERPRET,
    )(prices, ondemand)


def _row_stats_kernel(x_ref, mttr_ref, events_ref, frac_ref, *, h: int):
    """Row reductions over one (bm, H) band of the indicator matrix.

    events = Σ_h x·(1 - x_prev)   (below→above transitions, x_prev[0]=0)
    frac   = Σ_h x / H
    mttr   = (H - Σ_h x) / events, or H when the row never revoked.
    """
    x = x_ref[...]
    hf = jnp.float32(h)
    shifted = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    e = x * (1.0 - shifted)
    events = jnp.sum(e, axis=1)
    above = jnp.sum(x, axis=1)
    avail = hf - above
    frac_ref[...] = above / hf
    events_ref[...] = events
    mttr_ref[...] = jnp.where(events > 0.0, avail / jnp.maximum(events, 1.0), hf)


def row_stats(x: jnp.ndarray):
    """Pallas version of ref.row_stats: X[M,H] → (mttr, events, frac)[M]."""
    m, h = x.shape
    bm = pick_block(m)
    grid = (m // bm,)
    vec = jax.ShapeDtypeStruct((m,), jnp.float32)
    vec_spec = pl.BlockSpec((bm,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_row_stats_kernel, h=h),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, h), lambda i: (i, 0))],
        out_specs=(vec_spec, vec_spec, vec_spec),
        out_shape=(vec, vec, vec),
        interpret=INTERPRET,
    )(x)
