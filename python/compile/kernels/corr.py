"""Pallas kernel: revocation-correlation matrix (tiled X·Xᵀ on the MXU).

Layer-1 hot-spot.  The correlation between every pair of the M spot
markets is a centered, normalized Gram matrix of the indicator matrix
``X[M, H]`` — i.e. a matmul with a fused mean-subtraction on the inputs
and a fused rsqrt normalization on the output.  This is the one piece of
the P-SIWOFT pipeline that is genuinely MXU-shaped (the paper computes it
offline over "the past three months" of traces; we recompute it every
analytics epoch).

Tiling: grid ``(M/bm, M/bn)``; each step loads an A-band ``(bm, H)`` and
a B-band ``(bn, H)`` of X into VMEM together with the per-row mean/std
vectors, contracts the full H axis in one MXU pass, and writes a
``(bm, bn)`` tile of C.  For bm=bn=128, H=2160 (f32): 2·1.08 MB input
bands + 64 KB output ≈ 2.3 MB VMEM — comfortable double-buffering room.
A two-pass schedule (row-moments kernel, then the Gram kernel) avoids
recomputing means per tile-row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .indicators import INTERPRET, pick_block


def _row_moments_kernel(x_ref, mu_ref, sigma_ref, *, h: int):
    """Pass 1: per-row mean and (population) standard deviation."""
    x = x_ref[...]
    hf = jnp.float32(h)
    mu = jnp.sum(x, axis=1) / hf
    var = jnp.sum((x - mu[:, None]) ** 2, axis=1) / hf
    mu_ref[...] = mu
    sigma_ref[...] = jnp.sqrt(var)


def row_moments(x: jnp.ndarray):
    """X[M,H] → (mu[M], sigma[M]) in f32."""
    m, h = x.shape
    bm = pick_block(m)
    vec = jax.ShapeDtypeStruct((m,), jnp.float32)
    vec_spec = pl.BlockSpec((bm,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_row_moments_kernel, h=h),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, h), lambda i: (i, 0))],
        out_specs=(vec_spec, vec_spec),
        out_shape=(vec, vec),
        interpret=INTERPRET,
    )(x)


def _corr_tile_kernel(a_ref, b_ref, mu_i_ref, mu_j_ref, s_i_ref, s_j_ref,
                      c_ref, *, h: int):
    """Pass 2: one (bm, bn) tile of the correlation matrix.

    cov  = (A - μᵢ)(B - μⱼ)ᵀ / H        ← the MXU contraction
    corr = cov / (σᵢ σⱼ)  with zero-variance rows pinned to 0.
    """
    hf = jnp.float32(h)
    a = a_ref[...] - mu_i_ref[...][:, None]
    b = b_ref[...] - mu_j_ref[...][:, None]
    cov = jax.lax.dot_general(
        a, b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) / hf
    denom = s_i_ref[...][:, None] * s_j_ref[...][None, :]
    safe = jnp.where(denom > 0.0, denom, 1.0)
    c_ref[...] = jnp.where(denom > 0.0, cov / safe, 0.0)


def revocation_correlation(x: jnp.ndarray) -> jnp.ndarray:
    """Pallas version of ref.revocation_correlation: X[M,H] → C[M,M]."""
    m, h = x.shape
    bm = pick_block(m)
    mu, sigma = row_moments(x)
    band = lambda sel: pl.BlockSpec((bm, h), (lambda i, j: (i, 0)) if sel == 0 else (lambda i, j: (j, 0)))
    vec = lambda sel: pl.BlockSpec((bm,), (lambda i, j: (i,)) if sel == 0 else (lambda i, j: (j,)))
    corr = pl.pallas_call(
        functools.partial(_corr_tile_kernel, h=h),
        grid=(m // bm, m // bm),
        in_specs=[band(0), band(1), vec(0), vec(1), vec(0), vec(1)],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=INTERPRET,
    )(x, x, mu, mu, sigma, sigma)
    eye = jnp.eye(m, dtype=bool)
    return jnp.where(eye, 1.0, corr)
