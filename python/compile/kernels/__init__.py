"""Layer-1 Pallas kernels for P-SIWOFT market analytics."""

from . import corr, indicators, ref  # noqa: F401
