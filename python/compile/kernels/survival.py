"""Pallas kernel: empirical instance-survival curves per market.

Implements the duration-probability estimation of the paper's related
work ([17], Wolski et al.: "probabilistic guarantees of execution
duration for Amazon spot instances") as a Layer-1 kernel, consumed by
the Rust `policy::predictive` baseline.

Definition.  From the revocation-indicator matrix ``X[M, H]`` let
``A = 1 - X`` (available hours) and ``R[m, h]`` be the number of
consecutive available hours starting at ``h``:

    R[m, h] = A[m, h] * (R[m, h+1] + 1)        (reverse scan, R[m, H] = 0)

An instance provisioned at a uniformly random *available* hour survives
at least ``t`` hours with probability

    S[m, t] = #{h : R[m, h] >= t} / max(1, #{h : R[m, h] >= 1}).

``S[m, 1] = 1`` by construction; a never-revoked market decays linearly
(right-censoring at the window edge — mirrored exactly by the Rust
native implementation, see market/analytics.rs).

Kernel shape: one ``(bm, H)`` row band per grid step; the reverse scan
runs on the VPU, the T survival thresholds (default 64) are unrolled as
vector compare+reduce passes — no MXU needed, one HBM pass over X.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .indicators import INTERPRET, pick_block

#: survival thresholds, hours 1..64 (covers 2x the longest Fig. 1 job)
DEFAULT_T = 64


def run_lengths(x: jnp.ndarray) -> jnp.ndarray:
    """R[m, h] = consecutive available hours starting at h.

    Formulated as a *log-depth associative scan* rather than a
    sequential ``lax.scan``: with ``next_rev[h] = min_{k≥h, X[k]=1} k``
    (reverse cummin over revoked indices), ``R[h] = next_rev[h] - h`` on
    available hours.  The sequential scan lowered to an HLO while-loop
    that executed in ~16 ms through PJRT at 64×2160; the cummin lowers to
    ⌈log₂ H⌉ vectorized min steps (EXPERIMENTS.md §Perf, L1 iteration 2).
    """
    _, h = x.shape
    idx = jnp.arange(h, dtype=jnp.float32)
    rev_idx = jnp.where(x > 0.5, idx[None, :], jnp.float32(h))
    next_rev = jax.lax.associative_scan(jnp.minimum, rev_idx, reverse=True, axis=1)
    return jnp.where(x > 0.5, 0.0, next_rev - idx[None, :])


def _survival_kernel(x_ref, s_ref, *, t_buckets: int):
    x = x_ref[...]
    runs = run_lengths(x)
    cols = [jnp.sum((runs >= float(t)).astype(jnp.float32), axis=1)
            for t in range(1, t_buckets + 1)]
    surv = jnp.stack(cols, axis=1)  # (bm, T)
    denom = jnp.maximum(surv[:, 0], 1.0)
    s_ref[...] = surv / denom[:, None]


def survival_matrix(x: jnp.ndarray, t_buckets: int = DEFAULT_T) -> jnp.ndarray:
    """Pallas survival curves: X[M, H] → S[M, T] in f32."""
    m, h = x.shape
    bm = pick_block(m)
    return pl.pallas_call(
        functools.partial(_survival_kernel, t_buckets=t_buckets),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, h), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, t_buckets), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t_buckets), jnp.float32),
        interpret=INTERPRET,
    )(x)
