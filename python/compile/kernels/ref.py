"""Pure-jnp reference oracle for the market-analytics kernels.

This module is the *correctness contract* shared by three implementations:

  1. the Pallas kernels in ``indicators.py`` / ``corr.py`` (build-time,
     lowered into the AOT artifact),
  2. the lowered HLO artifact executed by the Rust runtime, and
  3. the native Rust fallback in ``rust/src/market/analytics.rs``.

All three must agree with the formulas below (f32 arithmetic, same
definitions).  The semantics follow §III-A of the P-SIWOFT paper:

  * a market is *revoked* in hour ``h`` when its spot price exceeds the
    corresponding on-demand price (customers won't bid above on-demand);
  * a *revocation event* is a below→above transition;
  * MTTR (the "spot instance lifetime") is the average number of
    available hours per revocation event, i.e. the expected time until a
    freshly provisioned instance is revoked;
  * the *revocation correlation* between two markets is the Pearson
    correlation of their hourly revocation indicators over the trailing
    window (the paper's "revoked at the same hour over the past three
    months").
"""

from __future__ import annotations

import jax.numpy as jnp


def indicator_matrix(prices: jnp.ndarray, ondemand: jnp.ndarray) -> jnp.ndarray:
    """X[m, h] = 1.0 where the spot price is above on-demand (revoked hour).

    prices: f32[M, H] hourly spot prices; ondemand: f32[M].
    """
    return (prices > ondemand[:, None]).astype(jnp.float32)


def event_matrix(x: jnp.ndarray) -> jnp.ndarray:
    """E[m, h] = 1.0 at each below→above transition (E[:, 0] = X[:, 0])."""
    shifted = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    return x * (1.0 - shifted)


def row_stats(x: jnp.ndarray):
    """Per-market statistics from the indicator matrix.

    Returns (mttr, events, frac_above), each f32[M]:
      events     — number of revocation events in the window,
      frac_above — fraction of hours spent above on-demand,
      mttr       — available-hours / events; the full window H when the
                   market never revoked (a lower bound on its lifetime).
    """
    h = jnp.float32(x.shape[1])
    e = event_matrix(x)
    events = jnp.sum(e, axis=1)
    above = jnp.sum(x, axis=1)
    frac_above = above / h
    avail = h - above
    mttr = jnp.where(events > 0.0, avail / jnp.maximum(events, 1.0), h)
    return mttr, events, frac_above


def revocation_correlation(x: jnp.ndarray) -> jnp.ndarray:
    """Pearson correlation C[M, M] of hourly revocation indicators.

    Zero-variance rows (never / always revoked) correlate 0 with
    everything; the diagonal is forced to 1.
    """
    m, h = x.shape
    hf = jnp.float32(h)
    mu = jnp.sum(x, axis=1) / hf
    xc = x - mu[:, None]
    cov = xc @ xc.T / hf
    sigma = jnp.sqrt(jnp.diag(cov))
    denom = sigma[:, None] * sigma[None, :]
    corr = jnp.where(denom > 0.0, cov / jnp.where(denom > 0.0, denom, 1.0), 0.0)
    eye = jnp.eye(m, dtype=bool)
    return jnp.where(eye, 1.0, corr).astype(jnp.float32)


def run_lengths(x: jnp.ndarray) -> jnp.ndarray:
    """R[m, h] = consecutive available (X==0) hours starting at h."""
    import numpy as np

    xn = np.asarray(x)
    m, h = xn.shape
    runs = np.zeros((m, h), np.float32)
    for mi in range(m):
        nxt = 0.0
        for hi in range(h - 1, -1, -1):
            nxt = (1.0 - xn[mi, hi]) * (nxt + 1.0)
            runs[mi, hi] = nxt
    return jnp.asarray(runs)


def survival_matrix(x: jnp.ndarray, t_buckets: int = 64) -> jnp.ndarray:
    """S[m, t] = P(a uniformly-chosen available start survives ≥ t+1 h)."""
    import numpy as np

    runs = np.asarray(run_lengths(x))
    m = runs.shape[0]
    surv = np.zeros((m, t_buckets), np.float32)
    for t in range(1, t_buckets + 1):
        surv[:, t - 1] = (runs >= t).sum(axis=1)
    denom = np.maximum(surv[:, 0], 1.0)
    return jnp.asarray(surv / denom[:, None])


def market_analytics(prices: jnp.ndarray, ondemand: jnp.ndarray):
    """Full reference pipeline: (mttr, events, frac_above, corr)."""
    x = indicator_matrix(prices, ondemand)
    mttr, events, frac_above = row_stats(x)
    corr = revocation_correlation(x)
    return mttr, events, frac_above, corr
