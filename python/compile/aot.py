"""AOT lowering: JAX model → HLO *text* artifacts for the Rust runtime.

HLO text — NOT ``lowered.compile()`` or serialized ``HloModuleProto`` —
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction
ids that the crate-side XLA (xla_extension 0.5.1) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are keyed by shape: ``market_analytics_{M}x{H}.hlo.txt``.
A ``manifest.json`` lists every artifact with its input/output shapes so
the Rust runtime (rust/src/runtime/analytics_rt.rs) can pick the right
executable — or fall back to the native implementation — without parsing
HLO.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts \
        --shapes 64x2160,256x2160
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import market_analytics, survival_model

DEFAULT_SHAPES = "16x168,64x2160,256x2160"

#: lowered entry points: name -> (callable, output-shape builder)
MODELS = {
    "market_analytics": (
        market_analytics,
        lambda m, h: [[m], [m], [m], [m, m]],
    ),
    "survival": (
        survival_model,
        lambda m, h: [[m, 64]],
    ),
}


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True so the
    Rust side unwraps one tuple literal)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_shape(m: int, h: int, model: str = "market_analytics") -> str:
    fn, _ = MODELS[model]
    prices = jax.ShapeDtypeStruct((m, h), jnp.float32)
    ondemand = jax.ShapeDtypeStruct((m,), jnp.float32)
    lowered = jax.jit(fn).lower(prices, ondemand)
    return to_hlo_text(lowered)


def build(out_dir: str, shapes: list[tuple[int, int]], force: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    entries = []
    for m, h in shapes:
        for model, (_, out_shapes) in MODELS.items():
            name = f"{model}_{m}x{h}.hlo.txt"
            path = os.path.join(out_dir, name)
            if force or not os.path.exists(path):
                text = lower_shape(m, h, model)
                with open(path, "w") as f:
                    f.write(text)
                print(f"wrote {path} ({len(text)} chars)")
            else:
                print(f"up-to-date {path}")
            entries.append(
                {
                    "name": model,
                    "file": name,
                    "markets": m,
                    "hours": h,
                    "inputs": [
                        {"dtype": "f32", "shape": [m, h]},
                        {"dtype": "f32", "shape": [m]},
                    ],
                    "outputs": [
                        {"dtype": "f32", "shape": s} for s in out_shapes(m, h)
                    ],
                }
            )
    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "artifacts": entries}, f, indent=2)
    print(f"wrote {manifest_path}")


def parse_shapes(s: str) -> list[tuple[int, int]]:
    out = []
    for part in s.split(","):
        m, h = part.strip().lower().split("x")
        out.append((int(m), int(h)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--shapes", default=DEFAULT_SHAPES,
                    help="comma-separated MxH list")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact exists")
    args = ap.parse_args()
    build(args.out_dir, parse_shapes(args.shapes), force=args.force)


if __name__ == "__main__":
    main()
