"""Survival-kernel correctness: Pallas vs the (loop-based) oracle."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import survival as surv_k
from tests.test_kernels import make_traces


class TestRunLengths:
    def test_hand_example(self):
        # X:   0 0 1 0 1 1 0 0   (1 = revoked hour)
        # R:   2 1 0 1 0 0 2 1
        x = jnp.asarray(np.array([[0, 0, 1, 0, 1, 1, 0, 0]], np.float32))
        got = np.asarray(surv_k.run_lengths(x))
        np.testing.assert_array_equal(got, [[2, 1, 0, 1, 0, 0, 2, 1]])

    @settings(max_examples=20, deadline=None)
    @given(st.tuples(st.integers(1, 12), st.integers(2, 64)), st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        got = np.asarray(surv_k.run_lengths(x))
        want = np.asarray(ref.run_lengths(x))
        np.testing.assert_array_equal(got, want)


class TestSurvivalMatrix:
    @settings(max_examples=20, deadline=None)
    @given(st.tuples(st.integers(1, 10), st.integers(4, 64)), st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        got = np.asarray(surv_k.survival_matrix(x, 16))
        want = np.asarray(ref.survival_matrix(x, 16))
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(st.tuples(st.integers(1, 10), st.integers(4, 48)), st.integers(0, 2**31 - 1))
    def test_monotone_nonincreasing_in_t(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        s = np.asarray(surv_k.survival_matrix(x, 16))
        assert (np.diff(s, axis=1) <= 1e-6).all(), "survival must not increase with t"
        assert (s >= -1e-6).all() and (s <= 1 + 1e-6).all()

    def test_always_available_is_censored_linear(self):
        x = jnp.zeros((1, 32), jnp.float32)
        s = np.asarray(surv_k.survival_matrix(x, 8))
        # runs = 32,31,...,1 → survivors(t) = 32-t+1; S(t) = (33-t)/32
        want = np.array([(33 - t) / 32 for t in range(1, 9)], np.float32)
        np.testing.assert_allclose(s[0], want, rtol=1e-6)

    def test_always_revoked_is_zero(self):
        x = jnp.ones((2, 16), jnp.float32)
        s = np.asarray(surv_k.survival_matrix(x, 8))
        assert (s == 0).all()

    def test_s1_is_one_when_any_available(self):
        prices, od = make_traces(6, 48, 3)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        s = np.asarray(surv_k.survival_matrix(x, 8))
        avail = np.asarray(x).sum(axis=1) < 48
        np.testing.assert_allclose(s[avail, 0], 1.0, rtol=1e-6)

    def test_volatile_decays_faster_than_stable(self):
        stable = np.zeros(64, np.float32)
        volatile = np.tile([0, 0, 0, 1], 16).astype(np.float32)
        x = jnp.asarray(np.stack([stable, volatile]))
        s = np.asarray(surv_k.survival_matrix(x, 8))
        assert s[0, 5] > s[1, 5]
