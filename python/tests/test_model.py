"""Layer-2 model tests: full pipeline vs oracle, shapes, determinism."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from tests.test_kernels import make_traces


class TestMarketAnalytics:
    @settings(max_examples=15, deadline=None)
    @given(st.tuples(st.integers(1, 16), st.integers(2, 64)),
           st.integers(0, 2**31 - 1))
    def test_matches_ref_pipeline(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        got = model.market_analytics(jnp.asarray(prices), jnp.asarray(od))
        want = ref.market_analytics(jnp.asarray(prices), jnp.asarray(od))
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-6)

    def test_output_shapes(self):
        prices, od = make_traces(8, 24, 0)
        mttr, events, frac, corr = model.market_analytics(
            jnp.asarray(prices), jnp.asarray(od))
        assert mttr.shape == (8,) and events.shape == (8,)
        assert frac.shape == (8,) and corr.shape == (8, 8)
        for t in (mttr, events, frac, corr):
            assert t.dtype == jnp.float32

    def test_jit_deterministic(self):
        prices, od = make_traces(8, 24, 42)
        f = jax.jit(model.market_analytics)
        a = f(jnp.asarray(prices), jnp.asarray(od))
        b = f(jnp.asarray(prices), jnp.asarray(od))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_jit_matches_eager(self):
        prices, od = make_traces(4, 32, 9)
        eager = model.market_analytics(jnp.asarray(prices), jnp.asarray(od))
        jitted = jax.jit(model.market_analytics)(jnp.asarray(prices), jnp.asarray(od))
        for x, y in zip(eager, jitted):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)
