"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps market counts / window lengths / price regimes and
asserts the Pallas kernels (interpret mode) match the pure-jnp oracle in
``ref.py`` to f32 tolerance, plus structural invariants the Rust side
relies on (symmetry, bounded correlations, MTTR ranges).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import corr as corr_k
from compile.kernels import indicators as ind_k
from compile.kernels import ref


def make_traces(m, h, seed, spike_prob=0.15, ratio=0.3):
    """Synthetic spot traces: baseline ratio·od with occasional spikes
    above on-demand — the regime the indicator kernels must classify."""
    rng = np.random.default_rng(seed)
    od = rng.uniform(0.5, 5.0, size=m).astype(np.float32)
    base = od * ratio
    noise = rng.lognormal(mean=0.0, sigma=0.25, size=(m, h)).astype(np.float32)
    spikes = (rng.random((m, h)) < spike_prob).astype(np.float32)
    prices = base[:, None] * noise * (1.0 + spikes * rng.uniform(2.0, 6.0, size=(m, h)).astype(np.float32))
    return prices.astype(np.float32), od


shapes = st.tuples(st.integers(1, 24), st.integers(2, 96))


class TestIndicatorMatrix:
    @settings(max_examples=25, deadline=None)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        got = ind_k.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        want = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_values_binary(self):
        prices, od = make_traces(8, 64, 7)
        x = np.asarray(ind_k.indicator_matrix(jnp.asarray(prices), jnp.asarray(od)))
        assert set(np.unique(x)).issubset({0.0, 1.0})

    def test_all_below(self):
        od = np.full(4, 10.0, np.float32)
        prices = np.full((4, 16), 1.0, np.float32)
        x = np.asarray(ind_k.indicator_matrix(jnp.asarray(prices), jnp.asarray(od)))
        assert x.sum() == 0.0

    def test_all_above(self):
        od = np.full(4, 1.0, np.float32)
        prices = np.full((4, 16), 10.0, np.float32)
        x = np.asarray(ind_k.indicator_matrix(jnp.asarray(prices), jnp.asarray(od)))
        assert x.sum() == 4 * 16

    def test_boundary_equal_price_not_revoked(self):
        # strict inequality: price == on-demand is NOT a revocation
        od = np.full(2, 3.0, np.float32)
        prices = np.full((2, 8), 3.0, np.float32)
        x = np.asarray(ind_k.indicator_matrix(jnp.asarray(prices), jnp.asarray(od)))
        assert x.sum() == 0.0


class TestRowStats:
    @settings(max_examples=25, deadline=None)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        got = ind_k.row_stats(x)
        want = ref.row_stats(x)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_mttr_bounds(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        mttr, events, frac = ind_k.row_stats(x)
        mttr, events, frac = map(np.asarray, (mttr, events, frac))
        assert (mttr >= 0).all() and (mttr <= h).all()
        assert (events >= 0).all() and (events <= (h + 1) // 2 + 1).all()
        assert (frac >= 0).all() and (frac <= 1).all()

    def test_never_revoked_gets_full_window(self):
        x = jnp.zeros((3, 48), jnp.float32)
        mttr, events, frac = map(np.asarray, ind_k.row_stats(x))
        assert (mttr == 48.0).all() and (events == 0).all() and (frac == 0).all()

    def test_always_revoked(self):
        x = jnp.ones((2, 48), jnp.float32)
        mttr, events, frac = map(np.asarray, ind_k.row_stats(x))
        # one event (the initial transition), zero available hours
        assert (events == 1.0).all() and (mttr == 0.0).all() and (frac == 1.0).all()

    def test_alternating_pattern(self):
        # 0,1,0,1,... over 8 hours: 4 events, 4 available hours → mttr 1
        x = jnp.asarray(np.tile([0.0, 1.0], 4)[None, :].astype(np.float32))
        mttr, events, frac = map(np.asarray, ind_k.row_stats(x))
        assert events[0] == 4.0 and mttr[0] == 1.0 and frac[0] == 0.5

    def test_single_event_run(self):
        # 0,0,1,1,1,0,0,0: one event, 5 available hours → mttr 5
        x = jnp.asarray(np.array([[0, 0, 1, 1, 1, 0, 0, 0]], np.float32))
        mttr, events, frac = map(np.asarray, ind_k.row_stats(x))
        assert events[0] == 1.0 and mttr[0] == 5.0


class TestCorrelation:
    @settings(max_examples=20, deadline=None)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        got = np.asarray(corr_k.revocation_correlation(x))
        want = np.asarray(ref.revocation_correlation(x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(shapes, st.integers(0, 2**31 - 1))
    def test_structural_invariants(self, shape, seed):
        m, h = shape
        prices, od = make_traces(m, h, seed)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        c = np.asarray(corr_k.revocation_correlation(x))
        np.testing.assert_allclose(c, c.T, atol=1e-6)          # symmetric
        np.testing.assert_allclose(np.diag(c), 1.0, atol=1e-6)  # unit diag
        assert (c <= 1.0 + 1e-5).all() and (c >= -1.0 - 1e-5).all()

    def test_identical_rows_fully_correlated(self):
        row = np.array([0, 1, 1, 0, 1, 0, 0, 1], np.float32)
        x = jnp.asarray(np.stack([row, row]))
        c = np.asarray(corr_k.revocation_correlation(x))
        np.testing.assert_allclose(c, 1.0, atol=1e-6)

    def test_anti_correlated_rows(self):
        row = np.array([0, 1, 1, 0, 1, 0, 0, 1], np.float32)
        x = jnp.asarray(np.stack([row, 1.0 - row]))
        c = np.asarray(corr_k.revocation_correlation(x))
        np.testing.assert_allclose(c[0, 1], -1.0, atol=1e-6)

    def test_zero_variance_rows_uncorrelated(self):
        x = jnp.asarray(np.array([[0, 0, 0, 0], [0, 1, 0, 1]], np.float32))
        c = np.asarray(corr_k.revocation_correlation(x))
        assert c[0, 1] == 0.0 and c[1, 0] == 0.0
        assert c[0, 0] == 1.0 and c[1, 1] == 1.0  # diagonal pinned even at σ=0

    def test_block_tiling_consistency(self):
        # M=128 exercises the multi-tile grid path (bm=128 → here 1 tile of
        # 128; M=8 with forced small blocks compared against full ref).
        prices, od = make_traces(16, 64, 123)
        x = ref.indicator_matrix(jnp.asarray(prices), jnp.asarray(od))
        got = np.asarray(corr_k.revocation_correlation(x))
        want = np.asarray(ref.revocation_correlation(x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestPickBlock:
    @pytest.mark.parametrize("m,expect", [(256, 128), (128, 128), (64, 64),
                                          (96, 32), (7, 7), (1, 1), (24, 8)])
    def test_divides(self, m, expect):
        b = ind_k.pick_block(m)
        assert b == expect and m % b == 0
