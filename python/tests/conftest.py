"""Test-suite bootstrap.

Puts ``python/`` on ``sys.path`` so ``from compile import ...`` works
when pytest is invoked from the repository root, and skips collection of
the property-based modules when ``hypothesis`` is not installed (the
offline build image ships JAX but not hypothesis; CI treats the Python
job as allowed-to-fail either way).
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

collect_ignore: list[str] = []
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore += ["test_kernels.py", "test_model.py", "test_survival.py"]
