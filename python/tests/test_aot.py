"""AOT lowering tests: HLO text round-trips and matches the manifest."""

from __future__ import annotations

import json
import os

from compile import aot


class TestLowering:
    def test_hlo_text_smoke(self):
        text = aot.lower_shape(4, 16)
        assert "HloModule" in text
        assert "f32[4,16]" in text       # prices input
        assert "f32[4,4]" in text        # correlation output
        assert len(text) > 1000

    def test_parse_shapes(self):
        assert aot.parse_shapes("64x2160,8x24") == [(64, 2160), (8, 24)]

    def test_build_writes_manifest(self, tmp_path):
        out = str(tmp_path)
        aot.build(out, [(4, 16)])
        with open(os.path.join(out, "manifest.json")) as f:
            man = json.load(f)
        assert man["version"] == 1
        by_name = {e["name"]: e for e in man["artifacts"]}
        assert set(by_name) == {"market_analytics", "survival"}
        ana = by_name["market_analytics"]
        assert ana["markets"] == 4 and ana["hours"] == 16
        assert ana["outputs"][3]["shape"] == [4, 4]
        surv = by_name["survival"]
        assert surv["outputs"][0]["shape"] == [4, 64]
        for e in man["artifacts"]:
            assert os.path.exists(os.path.join(out, e["file"]))

    def test_build_is_incremental(self, tmp_path, capsys):
        out = str(tmp_path)
        aot.build(out, [(4, 16)])
        capsys.readouterr()
        aot.build(out, [(4, 16)])
        assert "up-to-date" in capsys.readouterr().out
